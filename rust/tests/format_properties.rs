//! Property-based tests on the numeric-format invariants (DESIGN.md §6),
//! using the in-house `util::prop` harness.

use flashtrain::formats::baselines::{roundtrip, Scheme};
use flashtrain::formats::{bf16, companding, fp16, quant4,
                          weight_split, Correction, Target, GROUP};
use flashtrain::util::prop::{forall, FloatVec};

#[test]
fn prop_split_roundtrip_error_bound() {
    let gen = FloatVec { min_len: 1, max_len: 512, lo_exp: -40.0,
                         hi_exp: 30.0, multiple: 1 };
    forall(11, 300, &gen, |v| {
        for &x in v {
            let (b, r) = weight_split::compress(x, Correction::Int8,
                                                Target::Bf16);
            let tp = bf16::bf16_bits_to_f32(b);
            if !tp.is_finite() {
                continue; // |x| beyond bf16 max -> inf, like plain bf16
            }
            let y = weight_split::decompress(b, r, Correction::Int8,
                                             Target::Bf16);
            let ulp = 2f64.powi(bf16::ulp_exponent(b));
            let bound = ulp / 2.0 * (0.5 / 127.0) * 1.001 + 1e-45;
            if ((y - x) as f64).abs() > bound {
                return Err(format!("x={x} y={y} bound={bound}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_split_never_worse_than_downcast() {
    let gen = FloatVec::default();
    forall(12, 300, &gen, |v| {
        for &x in v {
            let e_ours = (roundtrip(x, Scheme::UlpInt8, Target::Bf16) - x)
                .abs();
            let e_down = (roundtrip(x, Scheme::NoCorrection, Target::Bf16)
                          - x)
                .abs();
            if !(e_ours <= e_down + 1e-45)
                && e_down.is_finite()
            {
                return Err(format!("x={x}: ours {e_ours} > plain {e_down}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_theta_prime_equals_plain_downcast() {
    // drop-in property: fwd/bwd sees exactly the bf16 downcast weights
    let gen = FloatVec::default();
    forall(13, 300, &gen, |v| {
        for &x in v {
            let (b, _) = weight_split::compress(x, Correction::Int8,
                                                Target::Bf16);
            let plain = bf16::f32_to_bf16_bits(x);
            if b != plain {
                return Err(format!("x={x}: {b:#x} != {plain:#x}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_momentum_quant_error_fraction_of_absmax() {
    let gen = FloatVec { min_len: GROUP, max_len: GROUP * 16,
                         lo_exp: -10.0, hi_exp: 4.0, multiple: GROUP };
    forall(14, 200, &gen, |v| {
        let n = v.len();
        let mut q = vec![0i8; n];
        let mut s = vec![0u16; n / GROUP];
        companding::quant_momentum(v, &mut q, &mut s);
        let mut out = vec![0f32; n];
        companding::dequant_momentum(&q, &s, &mut out);
        for (g, og) in v.chunks_exact(GROUP).zip(out.chunks_exact(GROUP)) {
            let absmax = g.iter().fold(0f32, |a, &b| a.max(b.abs()));
            if absmax == 0.0 || !absmax.is_finite()
                || fp16::round_f32_to_f16(absmax) == 0.0
                || fp16::round_f32_to_f16(absmax).is_infinite()
            {
                continue; // degenerate groups (f16 scale under/overflow)
            }
            for (a, b) in g.iter().zip(og) {
                if (a - b).abs() / absmax > 0.02 {
                    return Err(format!("err {} absmax {absmax}",
                                       (a - b).abs()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_variance_quant_nonneg_and_bounded() {
    let gen = FloatVec { min_len: GROUP, max_len: GROUP * 8,
                         lo_exp: -16.0, hi_exp: 2.0, multiple: GROUP };
    forall(15, 200, &gen, |v| {
        let sq: Vec<f32> = v.iter().map(|x| x * x).collect();
        let n = sq.len();
        let mut q = vec![0u8; n];
        let mut s = vec![0u16; n / GROUP];
        companding::quant_variance(&sq, &mut q, &mut s);
        let mut out = vec![0f32; n];
        companding::dequant_variance(&q, &s, &mut out);
        for (g, og) in sq.chunks_exact(GROUP).zip(out.chunks_exact(GROUP)) {
            let vmax = g.iter().fold(0f32, |a, &b| a.max(b));
            if vmax == 0.0 || !vmax.is_finite()
                || fp16::round_f32_to_f16(vmax.sqrt()) == 0.0
                || fp16::round_f32_to_f16(vmax.sqrt()).is_infinite()
            {
                continue;
            }
            for (a, b) in g.iter().zip(og) {
                if *b < 0.0 {
                    return Err("negative variance".into());
                }
                if (a - b).abs() / vmax > 0.02 {
                    return Err(format!("err {} vmax {vmax}",
                                       (a - b).abs()));
                }
            }
        }
        Ok(())
    });
}

/// Zero elements survive quantization exactly (φ_m(0) = 0, code 0,
/// dequant 0), whatever the rest of the group holds; and all-zero
/// groups take the `scale_pair` safe-scale path (stored scale bits 0,
/// normalization by 1.0) without producing NaN.
#[test]
fn prop_zero_elements_and_zero_groups_exact() {
    let gen = FloatVec { min_len: GROUP, max_len: GROUP * 8,
                         lo_exp: -20.0, hi_exp: 10.0, multiple: GROUP };
    forall(21, 200, &gen, |v| {
        // force group 0 to be all-zero, keep the rest as generated
        let mut v = v.clone();
        for x in &mut v[..GROUP] {
            *x = 0.0;
        }
        let n = v.len();
        let mut q = vec![0i8; n];
        let mut s = vec![0u16; n / GROUP];
        companding::quant_momentum(&v, &mut q, &mut s);
        if s[0] != 0 {
            return Err(format!("all-zero group scale bits {:#x}", s[0]));
        }
        let mut out = vec![f32::NAN; n];
        companding::dequant_momentum(&q, &s, &mut out);
        for (i, (&x, &y)) in v.iter().zip(&out).enumerate() {
            if x == 0.0 && y.to_bits() != 0.0f32.to_bits() {
                return Err(format!("zero at {i} came back {y}"));
            }
            if y.is_nan() {
                return Err(format!("NaN at {i} (x = {x})"));
            }
        }

        // same through the variance (sqrt-domain) path
        let sq: Vec<f32> = v.iter().map(|x| x * x).collect();
        let mut qv = vec![0u8; n];
        companding::quant_variance(&sq, &mut qv, &mut s);
        if s[0] != 0 {
            return Err("all-zero variance group scale".into());
        }
        companding::dequant_variance(&qv, &s, &mut out);
        for (i, &y) in out.iter().enumerate() {
            if y.is_nan() {
                return Err(format!("variance NaN at {i}"));
            }
        }
        Ok(())
    });
}

/// Group absmax at or beyond the f16 saturation boundary (65504):
/// the stored scale must saturate to f16::MAX (not inf), codes stay in
/// range, and dequantized values stay finite.
#[test]
fn prop_scale_saturation_at_f16_boundary() {
    // boundary absmax values planted into otherwise-random groups
    let boundary = [65504.0f32, 65505.0, 65519.9, 65520.0, 1e5, 1e30,
                    f32::MAX];
    let gen = FloatVec { min_len: GROUP, max_len: GROUP * 4,
                         lo_exp: -4.0, hi_exp: 15.0, multiple: GROUP };
    forall(22, 150, &gen, |v| {
        for &big in &boundary {
            let mut v = v.clone();
            let n = v.len();
            v[0] = big; // group 0 absmax >= f16 max
            let mut q = vec![0i8; n];
            let mut s = vec![0u16; n / GROUP];
            companding::quant_momentum(&v, &mut q, &mut s);
            let scale = fp16::f16_bits_to_f32(s[0]);
            if !scale.is_finite() {
                return Err(format!("scale inf for absmax {big}"));
            }
            if scale > fp16::MAX {
                return Err(format!("scale {scale} above f16 max"));
            }
            let mut out = vec![0f32; n];
            companding::dequant_momentum(&q, &s, &mut out);
            for (i, &y) in out.iter().enumerate() {
                if !y.is_finite() {
                    return Err(format!(
                        "non-finite dequant at {i} for absmax {big}"));
                }
            }
            // the boundary element keeps its sign and magnitude order
            if out[0] <= 0.0 {
                return Err(format!("absmax {big} dequantized to {}",
                                   out[0]));
            }
        }
        Ok(())
    });
}

/// φ_m / φ_m⁻¹ round-trip accuracy and monotonicity: companding is a
/// strictly monotone bijection on the finite range, so sorting must be
/// preserved through the round trip and the inverse must undo the map.
#[test]
fn prop_phi_roundtrip_monotone() {
    let gen = FloatVec { min_len: 2, max_len: 256, lo_exp: -20.0,
                         hi_exp: 10.0, multiple: 1 };
    forall(23, 300, &gen, |v| {
        let mut xs: Vec<f32> =
            v.iter().copied().filter(|x| x.is_finite()).collect();
        for &x in &xs {
            let z = companding::phi_m(x);
            if z.abs() >= 2.0 {
                return Err(format!("|phi_m({x})| = {z} >= 2"));
            }
            let back = companding::phi_m_inv(z);
            let err = (back - x).abs();
            let tol = x.abs().max(1.0) * 4e-6 * (1.0 + x.abs());
            if err > tol {
                return Err(format!("roundtrip {x} -> {z} -> {back}"));
            }
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // f32 rounding of the intermediate ops may wiggle results by a
        // few ulps for near-adjacent inputs, so monotonicity is checked
        // up to a tiny slack; genuine inversions are far larger.
        let mut prev_z = f32::NEG_INFINITY;
        let mut prev_rt = f32::NEG_INFINITY;
        for &x in &xs {
            let z = companding::phi_m(x);
            if z < prev_z - 1e-6 {
                return Err(format!(
                    "phi_m not monotone at {x}: {z} < {prev_z}"));
            }
            let rt = companding::phi_m_inv(z);
            let slack = (rt.abs() + prev_rt.abs()).max(1.0) * 1e-4;
            if prev_rt.is_finite() && rt < prev_rt - slack {
                return Err(format!(
                    "roundtrip not monotone at {x}: {rt} < {prev_rt}"));
            }
            prev_z = prev_z.max(z);
            prev_rt = prev_rt.max(rt);
        }
        Ok(())
    });
}

// --- 4-bit nibble-packed codecs (quant4 / mixed84) -----------------------

/// 4-bit momentum round-trip error stays under the documented
/// **0.15 × absmax** bound (z-grid step 1/7, |dφ_m⁻¹/dz| ≤ 2) on
/// every non-degenerate group.
#[test]
fn prop_quant4_momentum_error_fraction_of_absmax() {
    let gen = FloatVec { min_len: GROUP, max_len: GROUP * 16,
                         lo_exp: -10.0, hi_exp: 4.0, multiple: GROUP };
    forall(31, 200, &gen, |v| {
        let n = v.len();
        let mut q = vec![0u8; quant4::packed_len(n)];
        let mut s = vec![0u16; n / GROUP];
        quant4::quant_momentum4(v, &mut q, &mut s);
        let mut out = vec![0f32; n];
        quant4::dequant_momentum4(&q, &s, &mut out);
        for (g, og) in v.chunks_exact(GROUP).zip(out.chunks_exact(GROUP)) {
            let absmax = g.iter().fold(0f32, |a, &b| a.max(b.abs()));
            if absmax == 0.0 || !absmax.is_finite()
                || fp16::round_f32_to_f16(absmax) == 0.0
                || fp16::round_f32_to_f16(absmax).is_infinite()
            {
                continue; // degenerate groups (f16 scale under/overflow)
            }
            for (a, b) in g.iter().zip(og) {
                if (a - b).abs() / absmax > 0.15 {
                    return Err(format!("err {} absmax {absmax}",
                                       (a - b).abs()));
                }
            }
        }
        Ok(())
    });
}

/// 4-bit variance round-trip: decoded values are nonnegative and
/// within the documented **0.07 × absmax** bound (sqrt-domain grid
/// step 1/15) on every non-degenerate group.
#[test]
fn prop_quant4_variance_nonneg_and_bounded() {
    let gen = FloatVec { min_len: GROUP, max_len: GROUP * 8,
                         lo_exp: -16.0, hi_exp: 2.0, multiple: GROUP };
    forall(32, 200, &gen, |v| {
        let sq: Vec<f32> = v.iter().map(|x| x * x).collect();
        let n = sq.len();
        let mut q = vec![0u8; quant4::packed_len(n)];
        let mut s = vec![0u16; n / GROUP];
        quant4::quant_variance4(&sq, &mut q, &mut s);
        let mut out = vec![0f32; n];
        quant4::dequant_variance4(&q, &s, &mut out);
        for (g, og) in sq.chunks_exact(GROUP).zip(out.chunks_exact(GROUP)) {
            let vmax = g.iter().fold(0f32, |a, &b| a.max(b));
            if vmax == 0.0 || !vmax.is_finite()
                || fp16::round_f32_to_f16(vmax.sqrt()) == 0.0
                || fp16::round_f32_to_f16(vmax.sqrt()).is_infinite()
            {
                continue;
            }
            for (a, b) in g.iter().zip(og) {
                if *b < 0.0 {
                    return Err("negative variance".into());
                }
                if (a - b).abs() / vmax > 0.07 {
                    return Err(format!("err {} vmax {vmax}",
                                       (a - b).abs()));
                }
            }
        }
        Ok(())
    });
}

/// The 4-bit curve is monotone end to end: a sorted group quantizes
/// to non-decreasing codes and dequantizes to non-decreasing values
/// (the code table is strictly monotone, so ordering survives the
/// round trip exactly — no slack needed).
#[test]
fn prop_quant4_roundtrip_monotone_within_group() {
    let gen = FloatVec { min_len: GROUP, max_len: GROUP * 4,
                         lo_exp: -12.0, hi_exp: 6.0, multiple: GROUP };
    forall(33, 200, &gen, |v| {
        let mut g: Vec<f32> = v[..GROUP]
            .iter()
            .map(|&x| if x.is_finite() { x } else { 0.0 })
            .collect();
        g.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut q = vec![0u8; GROUP / 2];
        let mut s = vec![0u16; 1];
        quant4::quant_momentum4(&g, &mut q, &mut s);
        let mut out = vec![0f32; GROUP];
        quant4::dequant_momentum4(&q, &s, &mut out);
        for w in out.windows(2) {
            if w[1] < w[0] {
                return Err(format!(
                    "momentum decode not monotone: {} < {}", w[1], w[0]));
            }
        }
        // sqrt-domain path on the sorted squares (still sorted after
        // mapping |x| -> x², so re-sort the absolute values first)
        let mut sq: Vec<f32> = g.iter().map(|x| x * x).collect();
        sq.sort_by(|a, b| a.partial_cmp(b).unwrap());
        quant4::quant_variance4(&sq, &mut q, &mut s);
        quant4::dequant_variance4(&q, &s, &mut out);
        for w in out.windows(2) {
            if w[1] < w[0] {
                return Err(format!(
                    "variance decode not monotone: {} < {}", w[1], w[0]));
            }
        }
        Ok(())
    });
}

/// Nibble pack/unpack round-trips at every length, odd tails
/// included; the dangling high nibble of an odd tail is always zero.
#[test]
fn prop_quant4_pack_roundtrip_any_length() {
    let gen = FloatVec { min_len: 1, max_len: 257, lo_exp: -20.0,
                         hi_exp: 20.0, multiple: 1 };
    forall(34, 300, &gen, |v| {
        let nibbles: Vec<u8> =
            v.iter().map(|x| (x.to_bits() & 0xF) as u8).collect();
        let n = nibbles.len();
        let mut packed = vec![0u8; quant4::packed_len(n)];
        quant4::pack_nibbles(&nibbles, &mut packed);
        if n % 2 == 1 && packed[n / 2] >> 4 != 0 {
            return Err("odd-tail high nibble not zero".into());
        }
        let mut out = vec![0xFFu8; n];
        quant4::unpack_nibbles(&packed, &mut out);
        if out != nibbles {
            return Err(format!("pack/unpack mismatch at n={n}"));
        }
        Ok(())
    });
}

// slice-contract coverage: the quant4 entry points reject misshapen
// buffers loudly (complementing the dequant-side checks in the unit
// tests)

#[test]
#[should_panic(expected = "two 4-bit codes per byte")]
fn quant_momentum4_rejects_unpacked_len() {
    let m = vec![0f32; GROUP];
    let mut q = vec![0u8; GROUP]; // full-byte buffer: twice too long
    let mut s = vec![0u16; 1];
    quant4::quant_momentum4(&m, &mut q, &mut s);
}

#[test]
#[should_panic]
fn quant_momentum4_rejects_unaligned_len() {
    let m = vec![0f32; GROUP + 1];
    let mut q = vec![0u8; quant4::packed_len(GROUP + 1)];
    let mut s = vec![0u16; 1];
    quant4::quant_momentum4(&m, &mut q, &mut s);
}

#[test]
#[should_panic(expected = "ceil(n/2)")]
fn unpack_nibbles_rejects_wrong_packed_len() {
    let packed = vec![0u8; 2];
    let mut out = vec![0u8; 5]; // needs 3 packed bytes
    quant4::unpack_nibbles(&packed, &mut out);
}

#[test]
fn prop_f16_conversion_monotone() {
    let gen = FloatVec { min_len: 2, max_len: 128, lo_exp: -20.0,
                         hi_exp: 15.0, multiple: 1 };
    forall(16, 300, &gen, |v| {
        let mut sorted: Vec<f32> =
            v.iter().copied().filter(|x| x.is_finite()).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = f32::NEG_INFINITY;
        for &x in &sorted {
            let r = fp16::round_f32_to_f16(x);
            if r < prev {
                return Err(format!("non-monotone at {x}: {r} < {prev}"));
            }
            prev = r;
        }
        Ok(())
    });
}

#[test]
fn prop_bf16_conversion_monotone_and_exact_on_bf16_values() {
    let gen = FloatVec::default();
    forall(17, 300, &gen, |v| {
        for &x in v {
            let once = bf16::round_f32_to_bf16(x);
            let twice = bf16::round_f32_to_bf16(once);
            if !once.is_nan() && once.to_bits() != twice.to_bits() {
                return Err(format!("not idempotent at {x}"));
            }
        }
        Ok(())
    });
}
