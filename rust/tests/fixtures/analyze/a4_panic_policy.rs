// A4 negative fixture (never compiled — scanned as text by
// tests/static_analysis.rs under a synthetic rust/src/backend/ path).

pub fn hot(x: Option<u32>) -> u32 {
    x.unwrap()
}

pub fn justified(x: Option<u32>) -> u32 {
    // analyze: allow(panic_policy) — fixture: structurally
    // guaranteed present by the caller.
    x.expect("present")
}

pub fn strings_do_not_count() -> &'static str {
    "call .unwrap() and .expect() here all you like"
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_panic() {
        Some(1).unwrap();
        Some(2).expect("fine in tests");
    }
}
