// A3 negative fixture: a sharded pair table that dropped two pairs —
// one legacy, one 4-bit.  Scanned as text under the synthetic path
// rust/tests/backend_equivalence.rs.

const SHARDED_PAIRS: [(OptKind, Variant); 19] = [
    (OptKind::Sgd, Variant::Flash),
    (OptKind::Sgd, Variant::WeightSplit),
    (OptKind::Sgd, Variant::OptQuant),
    (OptKind::Sgd, Variant::NoCompand),
    (OptKind::Sgd, Variant::Quant4),
    (OptKind::Sgd, Variant::Mixed84),
    (OptKind::AdamW, Variant::Reference),
    (OptKind::AdamW, Variant::Flash),
    (OptKind::AdamW, Variant::WeightSplit),
    (OptKind::AdamW, Variant::OptQuant),
    (OptKind::AdamW, Variant::NoCompand),
    (OptKind::AdamW, Variant::Quant4),
    (OptKind::AdamW, Variant::Mixed84),
    (OptKind::Lion, Variant::Reference),
    (OptKind::Lion, Variant::Flash),
    (OptKind::Lion, Variant::WeightSplit),
    (OptKind::Lion, Variant::OptQuant),
    (OptKind::Lion, Variant::NoCompand),
    (OptKind::Lion, Variant::Quant4),
];
