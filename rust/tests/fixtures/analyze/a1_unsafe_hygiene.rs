// A1 negative fixture (never compiled — scanned as text by
// tests/static_analysis.rs under a synthetic rust/src/ path).

/// Justified: contiguous comment block above the keyword.
pub fn good(p: *const u8) -> u8 {
    // SAFETY: fixture — `p` is valid for reads by construction.
    unsafe { *p }
}

pub fn also_good(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: trailing justification on the same line
}

pub fn bad(p: *const u8) -> u8 {
    unsafe { *p }
}

// a comment that is not a justification

pub fn bad_too(p: *const u8) -> u8 {
    // this comment block has no justification keyword in it
    unsafe { *p }
}
