//! A6 fixture: a TrainConfig with a field (`undocumented_knob`)
//! missing from the docs Keys table; the paired a6_config.md also
//! documents a `ghost_key` that no longer exists here.

pub struct TrainConfig {
    pub lr: f64,
    pub steps: usize,
    pub undocumented_knob: bool,
}

impl TrainConfig {
    pub fn not_a_field(&self) -> usize {
        self.steps
    }
}
