// A3 negative fixture: a fuzz universe frozen at the pre-4-bit
// 15-pair world (no Quant4 / Mixed84).  Scanned as text under the
// synthetic path rust/tests/fused_fuzz.rs.

const ALL_OPTS: [OptKind; 3] =
    [OptKind::Sgd, OptKind::AdamW, OptKind::Lion];
const ALL_VARIANTS: [Variant; 5] = [
    Variant::Reference,
    Variant::Flash,
    Variant::WeightSplit,
    Variant::OptQuant,
    Variant::NoCompand,
];
