// A3 negative fixture: a fuzz universe that dropped Lion.  Scanned
// as text under the synthetic path rust/tests/fused_fuzz.rs.

const ALL_OPTS: [OptKind; 2] = [OptKind::Sgd, OptKind::AdamW];
const ALL_VARIANTS: [Variant; 5] = [
    Variant::Reference,
    Variant::Flash,
    Variant::WeightSplit,
    Variant::OptQuant,
    Variant::NoCompand,
];
