// A3 negative fixture: a KernelSet whose fused coverage dropped
// (Lion, Quant4) and grew an unmappable field, with a fused_step
// match that also lost the (Lion, Quant4) arm.  Scanned as text
// under the synthetic path rust/src/kernels/mod.rs.

pub struct KernelSet {
    pub fused_step_adamw: FusedFn,
    pub fused_step_sgdm: FusedFn,
    pub fused_step_lion: FusedFn,
    pub fused_step_adamw_nocompand: FusedFn,
    pub fused_step_sgdm_nocompand: FusedFn,
    pub fused_step_lion_nocompand: FusedFn,
    pub fused_step_adamw_reference: FusedFn,
    pub fused_step_sgdm_reference: FusedFn,
    pub fused_step_lion_reference: FusedFn,
    pub fused_step_adamw_wsplit: FusedFn,
    pub fused_step_sgdm_wsplit: FusedFn,
    pub fused_step_lion_wsplit: FusedFn,
    pub fused_step_adamw_quant: FusedFn,
    pub fused_step_sgdm_quant: FusedFn,
    pub fused_step_lion_quant: FusedFn,
    pub fused_step_adamw_quant4: FusedFn,
    pub fused_step_sgdm_quant4: FusedFn,
    pub fused_step_adamw_mixed84: FusedFn,
    pub fused_step_sgdm_mixed84: FusedFn,
    pub fused_step_lion_mixed84: FusedFn,
    pub fused_step_rmsprop: FusedFn,
}

impl KernelSet {
    pub fn fused_step(&self, opt: OptKind, variant: Variant) -> FusedFn {
        match (opt, variant) {
            (OptKind::AdamW, Variant::Flash) => self.fused_step_adamw,
            (OptKind::Sgd, Variant::Flash) => self.fused_step_sgdm,
            (OptKind::Lion, Variant::Flash) => self.fused_step_lion,
            (OptKind::AdamW, Variant::NoCompand) => todo(),
            (OptKind::Sgd, Variant::NoCompand) => todo(),
            (OptKind::Lion, Variant::NoCompand) => todo(),
            (OptKind::AdamW, Variant::Reference) => todo(),
            (OptKind::Sgd, Variant::Reference) => todo(),
            (OptKind::Lion, Variant::Reference) => todo(),
            (OptKind::AdamW, Variant::WeightSplit) => todo(),
            (OptKind::Sgd, Variant::WeightSplit) => todo(),
            (OptKind::Lion, Variant::WeightSplit) => todo(),
            (OptKind::AdamW, Variant::OptQuant) => todo(),
            (OptKind::Sgd, Variant::OptQuant) => todo(),
            (OptKind::Lion, Variant::OptQuant) => todo(),
            (OptKind::AdamW, Variant::Quant4) => todo(),
            (OptKind::Sgd, Variant::Quant4) => todo(),
            (OptKind::AdamW, Variant::Mixed84) => todo(),
            (OptKind::Sgd, Variant::Mixed84) => todo(),
            (OptKind::Lion, Variant::Mixed84) => todo(),
        }
    }
}
