// A2 negative fixture (never compiled — scanned as text by
// tests/static_analysis.rs under the synthetic path
// rust/src/kernels/avx2.rs).

fn fixture(a: __m256, b: __m256, c: __m256) -> __m256 {
    // allowlisted + correctly pinned RNE immediate: no findings
    let ok = _mm256_add_ps(a, b);
    let ok2 = _mm256_round_ps::<{
        _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC
    }>(ok);

    // forbidden: FMA contracts two roundings into one
    let bad_fma = _mm256_fmadd_ps(a, b, c);

    // not on the audited allowlist
    let bad_unknown = _mm256_madd_epi16(a, b);

    // non-RNE rounding immediate
    let bad_round = _mm256_round_ps::<{ _MM_FROUND_TO_ZERO }>(a);

    // immediate not pinned at the call site
    let bad_unpinned = _mm256_round_ps(a);
    bad_unpinned
}
