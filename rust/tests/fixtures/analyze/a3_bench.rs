// A3 negative fixture: the pre-totality 7-row bench table.  Scanned
// as text under the synthetic path rust/benches/kernel_hotpath.rs.

const STEP_ROWS: [(OptKind, Variant); 7] = [
    (OptKind::AdamW, Variant::Reference),
    (OptKind::AdamW, Variant::Flash),
    (OptKind::AdamW, Variant::WeightSplit),
    (OptKind::AdamW, Variant::OptQuant),
    (OptKind::AdamW, Variant::NoCompand),
    (OptKind::Sgd, Variant::Flash),
    (OptKind::Lion, Variant::Flash),
];
