//! Differential + end-to-end tests for the `FlashOptimizer` param-group
//! facade on the native backends (no artifacts required).
//!
//! Pins the acceptance criteria of the param-group redesign:
//! * a single-group `FlashOptimizer` is bit-exact to the bare
//!   `BucketOptimizer` path across every (optimizer, variant) pair;
//! * a two-group decay/no_decay run with different weight decay trains
//!   end-to-end on the native backend, checkpoints to v2, and reloads
//!   bit-exact.

use std::collections::BTreeMap;

use flashtrain::backend::make_backend;
use flashtrain::checkpoint;
use flashtrain::config::{BackendKind, GroupConfig, OptKind, TrainConfig,
                         Variant};
use flashtrain::formats::{bf16, GROUP};
use flashtrain::optim::{BucketOptimizer, FlashOptimizer, GroupSpec,
                        Hyper, HyperDefaults, State};
use flashtrain::runtime::artifact::LayoutEntry;
use flashtrain::runtime::{ModelInfo, ModelKind};
use flashtrain::util::rng::Rng;

const ALL_PAIRS: [(OptKind, Variant); 21] = [
    (OptKind::Sgd, Variant::Reference),
    (OptKind::Sgd, Variant::Flash),
    (OptKind::Sgd, Variant::WeightSplit),
    (OptKind::Sgd, Variant::OptQuant),
    (OptKind::Sgd, Variant::NoCompand),
    (OptKind::Sgd, Variant::Quant4),
    (OptKind::Sgd, Variant::Mixed84),
    (OptKind::AdamW, Variant::Reference),
    (OptKind::AdamW, Variant::Flash),
    (OptKind::AdamW, Variant::WeightSplit),
    (OptKind::AdamW, Variant::OptQuant),
    (OptKind::AdamW, Variant::NoCompand),
    (OptKind::AdamW, Variant::Quant4),
    (OptKind::AdamW, Variant::Mixed84),
    (OptKind::Lion, Variant::Reference),
    (OptKind::Lion, Variant::Flash),
    (OptKind::Lion, Variant::WeightSplit),
    (OptKind::Lion, Variant::OptQuant),
    (OptKind::Lion, Variant::NoCompand),
    (OptKind::Lion, Variant::Quant4),
    (OptKind::Lion, Variant::Mixed84),
];

fn randn(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * s).collect()
}

fn grad(rng: &mut Rng, n: usize, variant: Variant) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let x = rng.normal() as f32 * 0.01;
            if variant.splits_weights() {
                bf16::round_f32_to_bf16(x)
            } else {
                x
            }
        })
        .collect()
}

fn assert_states_bit_equal(a: &State, b: &State, what: &str) {
    assert_eq!(a.theta_p, b.theta_p, "{what} theta_p");
    assert_eq!(a.rho, b.rho, "{what} rho");
    assert_eq!(a.mq, b.mq, "{what} mq");
    assert_eq!(a.ms, b.ms, "{what} ms");
    assert_eq!(a.vq, b.vq, "{what} vq");
    assert_eq!(a.vs, b.vs, "{what} vs");
    assert_eq!(a.mq4, b.mq4, "{what} mq4");
    assert_eq!(a.vq4, b.vq4, "{what} vq4");
    let eq_f32 = |x: &Option<Vec<f32>>, y: &Option<Vec<f32>>| match (x, y) {
        (Some(x), Some(y)) => {
            x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        (None, None) => true,
        _ => false,
    };
    assert!(eq_f32(&a.theta, &b.theta), "{what} theta");
    assert!(eq_f32(&a.m, &b.m), "{what} m");
    assert!(eq_f32(&a.v, &b.v), "{what} v");
}

/// Synthetic model layout mixing decay-eligible matrices with norm
/// scales and biases.
fn lm_like_model() -> ModelInfo {
    let entries: [(&str, usize); 7] = [
        ("wte", 4 * GROUP),
        ("ln0.g", GROUP),
        ("h0.attn.w", 6 * GROUP),
        ("h0.attn.b", GROUP),
        ("h0.mlp.w", 5 * GROUP),
        ("lnf.g", GROUP),
        ("head", 2 * GROUP),
    ];
    let mut layout = Vec::new();
    let mut off = 0usize;
    for (name, n) in entries {
        layout.push(LayoutEntry { name: name.into(), offset: off,
                                  shape: vec![n] });
        off += n;
    }
    ModelInfo {
        name: "lm-like".into(),
        kind: ModelKind::Lm { vocab: 64, d_model: 16, n_layers: 1,
                              n_heads: 2, seq_len: 8 },
        batch: 4,
        param_count: off,
        layout,
        artifacts: BTreeMap::new(),
    }
}

/// Acceptance: a single-group `FlashOptimizer` run is bit-exact to
/// today's bare `BucketOptimizer` path, for every (optimizer, variant)
/// pair and on both native engines.
#[test]
fn single_group_bit_exact_to_bucket_optimizer_all_pairs() {
    let n = 6 * GROUP + 13; // unaligned tail on purpose
    let bucket = 2 * GROUP;
    for backend in [BackendKind::Scalar, BackendKind::Parallel] {
        for (opt, variant) in ALL_PAIRS {
            let cfg = TrainConfig { optimizer: opt,
                                    ..Default::default() };
            let mut rng = Rng::new(0xBEEF ^ (opt as u64));
            let t0 = randn(&mut rng, n, 0.1);
            let mut raw = BucketOptimizer::native(
                opt, variant, bucket, &t0,
                make_backend(backend, 3).unwrap())
                .unwrap();
            let mut facade = FlashOptimizer::native(
                opt, variant, bucket, &t0, GroupSpec::single(n),
                HyperDefaults::of(&cfg), backend, 3)
                .unwrap();
            for t in 1..=5usize {
                let g = grad(&mut rng, n, variant);
                let h = Hyper::for_step(&cfg, 1e-3, t);
                raw.step_all(&g, &h, |_| {}).unwrap();
                facade.step(&g, 1e-3, t, |_, _| {}).unwrap();
            }
            assert_eq!(facade.groups.len(), 1);
            assert_states_bit_equal(&raw.state, &facade.groups[0].opt.state,
                                    &format!("{opt}/{variant}/{backend}"));
            assert_eq!(raw.compute_weights_bf16(n),
                       facade.compute_weights_bf16(n),
                       "{opt}/{variant}/{backend} compute weights");
        }
    }
}

/// Acceptance: a two-group decay/no_decay config with different weight
/// decay trains end-to-end on the native backend, checkpoints to v2,
/// and reloads bit-exact (then keeps training identically).
#[test]
fn two_group_decay_split_trains_checkpoints_v2_reloads_bit_exact() {
    let model = lm_like_model();
    let n = model.param_count;
    let cfg = TrainConfig::default(); // adamw/flash, wd 0.1
    let specs = GroupSpec::from_config(&GroupConfig::decay_pair(), &model)
        .unwrap();
    assert_eq!(specs.len(), 2);
    assert_eq!(specs[1].hyper.weight_decay, Some(0.0));

    let t0 = randn(&mut Rng::new(7), n, 0.1);
    let mut opt = FlashOptimizer::native(
        OptKind::AdamW, Variant::Flash, 2 * GROUP, &t0, specs.clone(),
        HyperDefaults::of(&cfg), BackendKind::Parallel, 3)
        .unwrap();

    let mut rng = Rng::new(8);
    let mut steps_done = 0u64;
    for t in 1..=10usize {
        let g = grad(&mut rng, n, Variant::Flash);
        opt.step(&g, 1e-3, t, |_, _| {}).unwrap();
        steps_done = t as u64;
    }
    let w = opt.master_weights(n);
    assert!(w.iter().all(|x| x.is_finite()));

    // checkpoint to v2 and reload into a fresh optimizer
    let path = std::env::temp_dir().join(format!(
        "flashtrain_group_e2e_{}.flt", std::process::id()));
    let sd = opt.state_dict(steps_done);
    checkpoint::save_state_dict(&path, &sd).unwrap();
    let sd2 = checkpoint::load_state_dict(&path).unwrap();
    assert_eq!(sd2.groups.len(), 2);
    assert_eq!(sd2.groups[0].name, "decay");
    assert_eq!(sd2.groups[1].name, "no_decay");

    let mut opt2 = FlashOptimizer::native(
        OptKind::AdamW, Variant::Flash, 2 * GROUP, &t0, specs,
        HyperDefaults::of(&cfg), BackendKind::Scalar, 0)
        .unwrap();
    assert_eq!(opt2.load_state_dict(&sd2).unwrap(), steps_done);
    for (a, b) in opt.groups.iter().zip(&opt2.groups) {
        assert_states_bit_equal(&a.opt.state, &b.opt.state, &a.name);
    }
    assert_eq!(opt.master_weights(n), opt2.master_weights(n));

    // training continues identically after the reload (scalar engine is
    // bit-exact to parallel by the backend equivalence guarantee)
    for t in 11..=14usize {
        let g = grad(&mut rng, n, Variant::Flash);
        let g2 = g.clone();
        opt.step(&g, 1e-3, t, |_, _| {}).unwrap();
        opt2.step(&g2, 1e-3, t, |_, _| {}).unwrap();
    }
    assert_eq!(opt.compute_weights_bf16(n), opt2.compute_weights_bf16(n));
    std::fs::remove_file(path).ok();
}

/// The no_decay override changes the trajectory of norm/bias params
/// relative to a single-group run (weight decay really is per-group).
#[test]
fn decay_split_changes_no_decay_trajectory_only_via_wd() {
    let model = lm_like_model();
    let n = model.param_count;
    let cfg = TrainConfig::default();
    let mut rng = Rng::new(21);
    // nonzero init everywhere so decay has something to shrink
    let t0 = randn(&mut rng, n, 0.2);

    let mut grouped = FlashOptimizer::native(
        OptKind::AdamW, Variant::Reference, GROUP, &t0,
        GroupSpec::decay_split(&model), HyperDefaults::of(&cfg),
        BackendKind::Scalar, 0)
        .unwrap();
    let mut single = FlashOptimizer::native(
        OptKind::AdamW, Variant::Reference, GROUP, &t0,
        GroupSpec::single(n), HyperDefaults::of(&cfg),
        BackendKind::Scalar, 0)
        .unwrap();

    // zero gradients isolate the weight-decay term
    let g = vec![0f32; n];
    for t in 1..=3usize {
        grouped.step(&g, 1e-2, t, |_, _| {}).unwrap();
        single.step(&g, 1e-2, t, |_, _| {}).unwrap();
    }
    let wg = grouped.master_weights(n);
    let ws = single.master_weights(n);
    // decay-eligible params identical in both runs...
    let no_decay_ranges = &grouped.groups[1].ranges;
    let in_no_decay = |i: usize| {
        no_decay_ranges.iter().any(|&(lo, hi)| i >= lo && i < hi)
    };
    for i in 0..n {
        if in_no_decay(i) {
            // ...norms/biases kept exactly (wd 0) in the grouped run
            assert_eq!(wg[i].to_bits(), t0[i].to_bits(), "idx {i}");
            assert_ne!(ws[i].to_bits(), t0[i].to_bits(), "idx {i}");
        } else {
            assert_eq!(wg[i].to_bits(), ws[i].to_bits(), "idx {i}");
        }
    }
}

/// state_dict round-trips across every (optimizer, variant) pair with
/// two groups through the in-memory API (file format covered in
/// checkpoint_v2.rs).
#[test]
fn state_dict_all_pairs_two_groups() {
    let model = lm_like_model();
    let n = model.param_count;
    for (opt, variant) in ALL_PAIRS {
        let cfg = TrainConfig { optimizer: opt, ..Default::default() };
        let mut rng = Rng::new(0xC0FFEE ^ ((opt as u64) << 3));
        let t0 = randn(&mut rng, n, 0.1);
        let mk = || {
            FlashOptimizer::native(
                opt, variant, 3 * GROUP, &t0,
                GroupSpec::decay_split(&model), HyperDefaults::of(&cfg),
                BackendKind::Scalar, 0)
                .unwrap()
        };
        let mut a = mk();
        let g = grad(&mut rng, n, variant);
        a.step(&g, 1e-3, 1, |_, _| {}).unwrap();
        let sd = a.state_dict(1);
        sd.validate().unwrap();
        let mut b = mk();
        b.load_state_dict(&sd).unwrap();
        for (x, y) in a.groups.iter().zip(&b.groups) {
            assert_states_bit_equal(&x.opt.state, &y.opt.state,
                                    &format!("{opt}/{variant}/{}", x.name));
        }
    }
}
