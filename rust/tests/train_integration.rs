//! Integration: end-to-end training through the full three-layer stack
//! (requires `make artifacts`).

use std::path::PathBuf;

use flashtrain::checkpoint;
use flashtrain::config::{OptKind, TrainConfig, Variant};
use flashtrain::coordinator::Trainer;
use flashtrain::runtime::{Manifest, Runtime};

fn setup() -> Option<(Manifest, Runtime)> {
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping: artifacts not built");
            return None;
        }
    };
    Some((manifest, Runtime::cpu().unwrap()))
}

fn tiny_cfg(opt: OptKind, variant: Variant, steps: usize) -> TrainConfig {
    TrainConfig {
        preset: "lm-tiny".into(),
        optimizer: opt,
        variant,
        steps,
        lr: 1e-3,
        warmup: 5,
        bucket: 65536,
        eval_batches: 2,
        log_every: 1000,
        ..Default::default()
    }
}

#[test]
fn flash_adamw_loss_decreases() {
    let Some((manifest, rt)) = setup() else { return };
    let mut t = Trainer::new(tiny_cfg(OptKind::AdamW, Variant::Flash, 30),
                             &manifest, &rt)
        .unwrap();
    t.run(true).unwrap();
    let first = t.metrics.steps[0].loss;
    let last = t.metrics.final_loss(5);
    assert!(last < first - 0.3, "loss {first} -> {last}");
}

#[test]
fn flash_matches_reference_closely() {
    // The paper's core claim: identical data order => nearly identical
    // loss trajectories for reference vs flash.
    let Some((manifest, rt)) = setup() else { return };
    let steps = 25;
    let mut r = Trainer::new(
        tiny_cfg(OptKind::AdamW, Variant::Reference, steps), &manifest,
        &rt)
        .unwrap();
    r.run(true).unwrap();
    let mut f = Trainer::new(tiny_cfg(OptKind::AdamW, Variant::Flash,
                                      steps), &manifest, &rt)
        .unwrap();
    f.run(true).unwrap();
    for (a, b) in r.metrics.steps.iter().zip(&f.metrics.steps) {
        assert_eq!(a.step, b.step);
        assert!((a.loss - b.loss).abs() < 0.08,
                "step {}: ref {} vs flash {}", a.step, a.loss, b.loss);
    }
}

#[test]
fn all_optimizers_and_ablations_train() {
    let Some((manifest, rt)) = setup() else { return };
    for (opt, variant) in [
        (OptKind::Sgd, Variant::Flash),
        (OptKind::Lion, Variant::Flash),
        (OptKind::AdamW, Variant::WeightSplit),
        (OptKind::AdamW, Variant::OptQuant),
    ] {
        let mut cfg = tiny_cfg(opt, variant, 6);
        if opt == OptKind::Sgd {
            cfg.lr = 0.05;
        }
        let mut t = Trainer::new(cfg, &manifest, &rt).unwrap();
        t.run(true).unwrap();
        let last = t.metrics.final_loss(2);
        assert!(last.is_finite(), "{opt}/{variant} diverged");
        assert!(last < t.metrics.steps[0].loss + 0.2,
                "{opt}/{variant} loss grew");
    }
}

#[test]
fn data_parallel_workers_reduce() {
    let Some((manifest, rt)) = setup() else { return };
    let mut cfg = tiny_cfg(OptKind::AdamW, Variant::Flash, 4);
    cfg.workers = 2;
    let mut t = Trainer::new(cfg, &manifest, &rt).unwrap();
    t.run(true).unwrap();
    assert_eq!(t.metrics.steps.len(), 4);
    assert!(t.metrics.final_loss(1).is_finite());
}

#[test]
fn identical_seeds_identical_runs() {
    let Some((manifest, rt)) = setup() else { return };
    let mk = || {
        let mut t = Trainer::new(
            tiny_cfg(OptKind::AdamW, Variant::Flash, 5), &manifest, &rt)
            .unwrap();
        t.run(true).unwrap();
        t.metrics
            .steps
            .iter()
            .map(|r| r.loss)
            .collect::<Vec<_>>()
    };
    assert_eq!(mk(), mk());
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some((manifest, rt)) = setup() else { return };
    let cfg = tiny_cfg(OptKind::AdamW, Variant::Flash, 3);
    let mut t = Trainer::new(cfg.clone(), &manifest, &rt).unwrap();
    t.run(true).unwrap();
    let path: PathBuf = std::env::temp_dir()
        .join(format!("flashtrain_it_{}.flt", std::process::id()));
    checkpoint::save_state_dict(&path, &t.state_dict()).unwrap();
    let sd = checkpoint::load_state_dict(&path).unwrap();
    assert_eq!(sd.step, 3);
    assert_eq!(sd.groups.len(), 1);
    let st = &sd.groups[0].state;
    let live = &t.opt.groups[0].opt.state;
    assert_eq!(st.theta_p, live.theta_p);
    assert_eq!(st.vq, live.vq);
    // compact: ~5.1 bytes/param over padded length
    let bpp = st.bytes() as f64 / st.n as f64;
    assert!((bpp - 5.125).abs() < 0.01, "{bpp}");

    // reload into a fresh trainer bit-exactly
    let mut t2 = Trainer::new(cfg, &manifest, &rt).unwrap();
    t2.load_state_dict(&sd).unwrap();
    assert_eq!(t2.current_step(), 3);
    let p = t.model.param_count;
    assert_eq!(t.opt.compute_weights_bf16(p),
               t2.opt.compute_weights_bf16(p));
    std::fs::remove_file(path).ok();
}

#[test]
fn two_group_config_trains_and_checkpoints_v2() {
    let Some((manifest, rt)) = setup() else { return };
    use flashtrain::config::GroupConfig;
    let mut cfg = tiny_cfg(OptKind::AdamW, Variant::Flash, 4);
    cfg.groups = GroupConfig::decay_pair();
    let mut t = Trainer::new(cfg.clone(), &manifest, &rt).unwrap();
    assert_eq!(t.opt.groups.len(), 2);
    assert_eq!(t.opt.groups[0].name, "decay");
    assert_eq!(t.opt.groups[1].name, "no_decay");
    assert_eq!(t.opt.groups[0].count() + t.opt.groups[1].count(),
               t.model.param_count);
    t.run(true).unwrap();
    assert!(t.metrics.final_loss(2).is_finite());

    let path: PathBuf = std::env::temp_dir()
        .join(format!("flashtrain_it_groups_{}.flt", std::process::id()));
    checkpoint::save_state_dict(&path, &t.state_dict()).unwrap();
    let sd = checkpoint::load_state_dict(&path).unwrap();
    assert_eq!(sd.groups.len(), 2);
    let mut t2 = Trainer::new(cfg, &manifest, &rt).unwrap();
    t2.load_state_dict(&sd).unwrap();
    let p = t.model.param_count;
    assert_eq!(t.opt.master_weights(p), t2.opt.master_weights(p));
    std::fs::remove_file(path).ok();
}

#[test]
fn bad_preset_and_bucket_are_clean_errors() {
    let Some((manifest, rt)) = setup() else { return };
    let mut cfg = tiny_cfg(OptKind::AdamW, Variant::Flash, 1);
    cfg.preset = "no-such-model".into();
    let err = match Trainer::new(cfg, &manifest, &rt) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error for bad preset"),
    };
    assert!(err.contains("no-such-model"), "{err}");

    let mut cfg = tiny_cfg(OptKind::AdamW, Variant::Flash, 1);
    cfg.bucket = 12345; // not in manifest
    let err = match Trainer::new(cfg, &manifest, &rt) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for bad bucket"),
    };
    assert!(err.contains("12345"), "{err}");
}

#[test]
fn unsupported_ablation_for_sgd_is_clean_error() {
    let Some((manifest, rt)) = setup() else { return };
    let cfg = tiny_cfg(OptKind::Sgd, Variant::OptQuant, 1);
    let err = match Trainer::new(cfg, &manifest, &rt) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected error for sgd ablation"),
    };
    assert!(err.contains("ablation") || err.contains("no artifact"),
            "{err}");
}

#[test]
fn vision_track_trains_and_learns() {
    let Some((manifest, rt)) = setup() else { return };
    let cfg = TrainConfig {
        preset: "vision".into(),
        optimizer: OptKind::Sgd,
        variant: Variant::Flash,
        steps: 40,
        lr: 0.05,
        warmup: 5,
        beta1: 0.9,
        weight_decay: 3e-5,
        bucket: 16384,
        eval_batches: 4,
        log_every: 1000,
        ..Default::default()
    };
    let mut t = Trainer::new(cfg, &manifest, &rt).unwrap();
    t.run(true).unwrap();
    let (_, acc) = t.evaluate().unwrap();
    assert!(acc > 0.3, "vision accuracy {acc} not above chance (0.1)");
}

#[test]
fn grad_release_reduces_tracked_gradient_peak() {
    let Some((manifest, rt)) = setup() else { return };
    use flashtrain::memory::tracker::Category;
    let mut with = tiny_cfg(OptKind::AdamW, Variant::Flash, 2);
    with.grad_release = true;
    let mut without = with.clone();
    without.grad_release = false;

    let mut tw = Trainer::new(with, &manifest, &rt).unwrap();
    tw.run(true).unwrap();
    let mut tn = Trainer::new(without, &manifest, &rt).unwrap();
    tn.run(true).unwrap();
    let g_with = tw.tracker.category_peak(Category::Gradients);
    let g_without = tn.tracker.category_peak(Category::Gradients);
    assert!(g_with < g_without / 2,
            "release {g_with} vs retain {g_without}");
}
