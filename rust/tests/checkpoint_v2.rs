//! Checkpoint v2 (named param-group sections) integration tests:
//! round-trips across every (optimizer, variant) pair with ≥2 groups,
//! v1 → v2 read-compat, per-section corruption injection on group
//! payloads and headers, and serial ↔ sharded writer/reader
//! equivalence (parallel per-shard CRC I/O must be byte-identical).

use std::path::PathBuf;

use flashtrain::backend::pool::WorkerPool;
use flashtrain::checkpoint;
use flashtrain::config::{OptKind, Variant};
use flashtrain::formats::GROUP;
use flashtrain::optim::{GroupState, State, StateDict};
use flashtrain::util::rng::Rng;

const ALL_PAIRS: [(OptKind, Variant); 21] = [
    (OptKind::Sgd, Variant::Reference),
    (OptKind::Sgd, Variant::Flash),
    (OptKind::Sgd, Variant::WeightSplit),
    (OptKind::Sgd, Variant::OptQuant),
    (OptKind::Sgd, Variant::NoCompand),
    (OptKind::Sgd, Variant::Quant4),
    (OptKind::Sgd, Variant::Mixed84),
    (OptKind::AdamW, Variant::Reference),
    (OptKind::AdamW, Variant::Flash),
    (OptKind::AdamW, Variant::WeightSplit),
    (OptKind::AdamW, Variant::OptQuant),
    (OptKind::AdamW, Variant::NoCompand),
    (OptKind::AdamW, Variant::Quant4),
    (OptKind::AdamW, Variant::Mixed84),
    (OptKind::Lion, Variant::Reference),
    (OptKind::Lion, Variant::Flash),
    (OptKind::Lion, Variant::WeightSplit),
    (OptKind::Lion, Variant::OptQuant),
    (OptKind::Lion, Variant::NoCompand),
    (OptKind::Lion, Variant::Quant4),
    (OptKind::Lion, Variant::Mixed84),
];

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("flashtrain_ckptv2_{}_{name}",
                                      std::process::id()))
}

fn theta(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
}

/// Three-group dict (uneven sizes, one group with split ranges).
fn demo_dict(opt: OptKind, variant: Variant, seed: u64) -> StateDict {
    let (a, b, c) = (4 * GROUP, 2 * GROUP, 3 * GROUP);
    let total = (a + b + c) as u64;
    StateDict {
        optimizer: opt,
        variant,
        step: 123,
        total_params: total,
        groups: vec![
            GroupState {
                name: "embeds".into(),
                param_count: a as u64,
                // split ranges: head + tail of the flat vector
                ranges: vec![(0, (a / 2) as u64),
                             (total - (a / 2) as u64, total)],
                state: State::init(&theta(a, seed), a, opt, variant),
            },
            GroupState {
                name: "no_decay".into(),
                param_count: b as u64,
                ranges: vec![((a / 2) as u64, (a / 2 + b) as u64)],
                state: State::init(&theta(b, seed + 1), b, opt, variant),
            },
            GroupState {
                name: "body".into(),
                param_count: c as u64,
                ranges: vec![((a / 2 + b) as u64,
                              (a / 2 + b + c) as u64)],
                state: State::init(&theta(c, seed + 2), c, opt, variant),
            },
        ],
    }
}

fn assert_states_bit_equal(x: &State, y: &State, what: &str) {
    assert_eq!(x.n, y.n, "{what} n");
    assert_eq!(x.theta_p, y.theta_p, "{what} theta_p");
    assert_eq!(x.rho, y.rho, "{what} rho");
    assert_eq!(x.mq, y.mq, "{what} mq");
    assert_eq!(x.ms, y.ms, "{what} ms");
    assert_eq!(x.vq, y.vq, "{what} vq");
    assert_eq!(x.vs, y.vs, "{what} vs");
    assert_eq!(x.mq4, y.mq4, "{what} mq4");
    assert_eq!(x.vq4, y.vq4, "{what} vq4");
    let eq_f32 = |p: &Option<Vec<f32>>, q: &Option<Vec<f32>>| match (p, q) {
        (Some(p), Some(q)) => {
            p.iter().zip(q).all(|(s, t)| s.to_bits() == t.to_bits())
        }
        (None, None) => true,
        _ => false,
    };
    assert!(eq_f32(&x.theta, &y.theta), "{what} theta");
    assert!(eq_f32(&x.m, &y.m), "{what} m");
    assert!(eq_f32(&x.v, &y.v), "{what} v");
}

#[test]
fn v2_roundtrip_all_pairs_three_groups() {
    for (i, (opt, variant)) in ALL_PAIRS.iter().enumerate() {
        let sd = demo_dict(*opt, *variant, i as u64 * 10 + 1);
        let path = tmp(&format!("rt_{opt}_{variant}"));
        checkpoint::save_state_dict(&path, &sd).unwrap();
        let sd2 = checkpoint::load_state_dict(&path).unwrap();
        assert_eq!(sd2.optimizer, *opt);
        assert_eq!(sd2.variant, *variant);
        assert_eq!(sd2.step, 123);
        assert_eq!(sd2.total_params, sd.total_params);
        assert_eq!(sd2.groups.len(), 3);
        for (a, b) in sd.groups.iter().zip(&sd2.groups) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.param_count, b.param_count);
            assert_eq!(a.ranges, b.ranges);
            assert_states_bit_equal(&a.state, &b.state,
                                    &format!("{opt}/{variant}/{}", a.name));
        }
        std::fs::remove_file(path).ok();
    }
}

#[test]
fn v1_files_load_as_single_all_group() {
    for (opt, variant) in [(OptKind::AdamW, Variant::Flash),
                           (OptKind::Sgd, Variant::Reference),
                           (OptKind::Lion, Variant::OptQuant)] {
        let n = 5 * GROUP;
        let st = State::init(&theta(n, 42), n, opt, variant);
        let path = tmp(&format!("v1_{opt}_{variant}"));
        checkpoint::save(&path, &st, opt, variant, 77, (n - 3) as u64)
            .unwrap();
        let sd = checkpoint::load_state_dict(&path).unwrap();
        assert_eq!(sd.optimizer, opt);
        assert_eq!(sd.variant, variant);
        assert_eq!(sd.step, 77);
        assert_eq!(sd.total_params, (n - 3) as u64);
        assert_eq!(sd.groups.len(), 1);
        assert_eq!(sd.groups[0].name, "all");
        assert_eq!(sd.groups[0].ranges, vec![(0, (n - 3) as u64)]);
        assert_states_bit_equal(&st, &sd.groups[0].state, "v1 compat");
        std::fs::remove_file(path).ok();
    }
}

/// Walk the v2 layout and return (label, payload_offset, payload_len)
/// for the file header, every group header, and every section payload.
fn v2_regions(bytes: &[u8]) -> Vec<(String, usize, usize)> {
    let u32_at = |p: usize| {
        u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap()) as usize
    };
    let u64_at = |p: usize| {
        u64::from_le_bytes(bytes[p..p + 8].try_into().unwrap()) as usize
    };
    assert_eq!(&bytes[..8], b"FLTCKPT1");
    assert_eq!(u32_at(8), 2, "not a v2 file");
    let mut out = Vec::new();
    out.push(("file_header".to_string(), 12, 22));
    let n_groups = u32_at(12 + 18);
    let mut p = 12 + 22 + 4;
    for gi in 0..n_groups {
        let gh_len = u32_at(p);
        out.push((format!("group{gi}_header"), p + 4, gh_len));
        p += 4 + gh_len + 4;
        let n_sections = u32_at(p);
        p += 4;
        for si in 0..n_sections {
            let tag = bytes[p];
            let len = u64_at(p + 1);
            out.push((format!("group{gi}_section{si}_tag{tag}"), p + 9,
                      len));
            p += 9 + len + 4;
        }
    }
    assert_eq!(p, bytes.len(), "walker covered the whole file");
    out
}

#[test]
fn per_section_corruption_injection_detected() {
    let sd = demo_dict(OptKind::AdamW, Variant::Flash, 99);
    let path = tmp("corrupt");
    checkpoint::save_state_dict(&path, &sd).unwrap();
    let clean = std::fs::read(&path).unwrap();
    let regions = v2_regions(&clean);
    // flash adamw: 6 sections per group x 3 groups + 4 headers
    assert!(regions.len() >= 3 * 6 + 4, "{}", regions.len());

    for (label, off, len) in &regions {
        if *len == 0 {
            continue;
        }
        let mut bytes = clean.clone();
        bytes[off + len / 2] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = match checkpoint::load_state_dict(&path) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("corruption in {label} went undetected"),
        };
        assert!(
            err.contains("crc") || err.contains("corrupt")
                || err.contains("tag") || err.contains("length")
                || err.contains("invalid") || err.contains("byte"),
            "{label}: unexpected error {err}"
        );
    }
    // the pristine file still loads after all that
    std::fs::write(&path, &clean).unwrap();
    checkpoint::load_state_dict(&path).unwrap();
    std::fs::remove_file(path).ok();
}

/// The nibble-packed 4-bit sections (tags 9/10) round-trip through
/// v2 and are individually CRC-protected: a flipped bit in any
/// mq4/vq4 payload is caught by both loaders, and the packed section
/// is half the byte size of its 8-bit counterpart.
#[test]
fn nibble_packed_sections_roundtrip_and_detect_corruption() {
    let sd = demo_dict(OptKind::AdamW, Variant::Quant4, 421);
    let path = tmp("nibble");
    checkpoint::save_state_dict(&path, &sd).unwrap();
    let clean = std::fs::read(&path).unwrap();

    // locate the 4-bit sections by tag (Mq4U8 = 9, Vq4U8 = 10): one of
    // each per group, with exactly n/2 payload bytes
    let regions = v2_regions(&clean);
    let nibble: Vec<_> = regions
        .iter()
        .filter(|(label, _, _)| {
            label.ends_with("tag9") || label.ends_with("tag10")
        })
        .collect();
    assert_eq!(nibble.len(), 2 * sd.groups.len(),
               "one mq4 and one vq4 section per group");
    for (gs, pair) in sd.groups.iter().zip(nibble.chunks(2)) {
        for (label, _, len) in pair {
            assert_eq!(*len, gs.state.n / 2,
                       "{label}: packed section must be n/2 bytes");
        }
    }

    // clean round-trip, both loaders
    let pool = WorkerPool::new(2).unwrap();
    for sd2 in [checkpoint::load_state_dict(&path).unwrap(),
                checkpoint::load_state_dict_sharded(&path, &pool)
                    .unwrap()] {
        assert_eq!(sd2.variant, Variant::Quant4);
        for (a, b) in sd.groups.iter().zip(&sd2.groups) {
            assert!(b.state.mq4.is_some() && b.state.vq4.is_some(),
                    "{}: 4-bit buffers must survive the round trip",
                    a.name);
            assert_states_bit_equal(&a.state, &b.state,
                                    &format!("quant4 rt {}", a.name));
        }
    }

    // flip one bit in every nibble-packed payload: both loaders fail
    for (label, off, len) in &nibble {
        let mut bytes = clean.clone();
        bytes[off + len / 2] ^= 0x04;
        std::fs::write(&path, &bytes).unwrap();
        for loader in ["serial", "sharded"] {
            let res = if loader == "serial" {
                checkpoint::load_state_dict(&path).map(|_| ())
            } else {
                checkpoint::load_state_dict_sharded(&path, &pool)
                    .map(|_| ())
            };
            let err = match res {
                Err(e) => format!("{e:#}"),
                Ok(()) => panic!(
                    "corruption in {label} undetected by the {loader} \
                     loader"),
            };
            assert!(err.contains("crc") || err.contains("corrupt"),
                    "{label}/{loader}: unexpected error {err}");
        }
    }
    std::fs::write(&path, &clean).unwrap();
    checkpoint::load_state_dict(&path).unwrap();
    std::fs::remove_file(path).ok();
}

#[test]
fn sharded_writer_is_byte_identical_for_all_pairs() {
    // the parallel writer must emit the exact serial v2 bytes for
    // every (optimizer, variant) state shape, at any worker count —
    // including section payloads whose length is not a multiple of
    // the shard count
    for (i, (opt, variant)) in ALL_PAIRS.iter().enumerate() {
        let sd = demo_dict(*opt, *variant, i as u64 * 10 + 500);
        let p_ser = tmp(&format!("shardser_{opt}_{variant}"));
        checkpoint::save_state_dict(&p_ser, &sd).unwrap();
        let want = std::fs::read(&p_ser).unwrap();
        for workers in [0usize, 3] {
            let pool = WorkerPool::new(workers).unwrap();
            let p_par = tmp(&format!("shardpar{workers}_{opt}_{variant}"));
            checkpoint::save_state_dict_sharded(&p_par, &sd, &pool)
                .unwrap();
            let got = std::fs::read(&p_par).unwrap();
            assert!(want == got,
                    "{opt}/{variant} workers={workers}: sharded bytes \
                     differ from serial");
            std::fs::remove_file(p_par).ok();
        }
        std::fs::remove_file(p_ser).ok();
    }
}

#[test]
fn sharded_and_serial_loaders_cross_read() {
    let sd = demo_dict(OptKind::AdamW, Variant::Flash, 77);
    let pool = WorkerPool::new(2).unwrap();
    let path = tmp("cross");
    for sharded_writer in [false, true] {
        if sharded_writer {
            checkpoint::save_state_dict_sharded(&path, &sd, &pool)
                .unwrap();
        } else {
            checkpoint::save_state_dict(&path, &sd).unwrap();
        }
        let serial = checkpoint::load_state_dict(&path).unwrap();
        let shard = checkpoint::load_state_dict_sharded(&path, &pool)
            .unwrap();
        for sd2 in [&serial, &shard] {
            assert_eq!(sd2.step, 123);
            assert_eq!(sd2.groups.len(), 3);
            for (a, b) in sd.groups.iter().zip(&sd2.groups) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.ranges, b.ranges);
                assert_states_bit_equal(
                    &a.state, &b.state,
                    &format!("writer_sharded={sharded_writer}/{}", a.name));
            }
        }
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn sharded_loader_detects_per_section_corruption() {
    // same injection walk as the serial loader's test: the pooled CRC
    // verification must catch a flip in every header and payload
    let sd = demo_dict(OptKind::AdamW, Variant::Flash, 99);
    let pool = WorkerPool::new(3).unwrap();
    let path = tmp("shardcorrupt");
    checkpoint::save_state_dict_sharded(&path, &sd, &pool).unwrap();
    let clean = std::fs::read(&path).unwrap();
    for (label, off, len) in &v2_regions(&clean) {
        if *len == 0 {
            continue;
        }
        let mut bytes = clean.clone();
        bytes[off + len / 2] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = match checkpoint::load_state_dict_sharded(&path, &pool) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("corruption in {label} went undetected"),
        };
        assert!(
            err.contains("crc") || err.contains("corrupt")
                || err.contains("tag") || err.contains("length")
                || err.contains("invalid") || err.contains("byte"),
            "{label}: unexpected error {err}"
        );
    }
    std::fs::write(&path, &clean).unwrap();
    checkpoint::load_state_dict_sharded(&path, &pool).unwrap();
    std::fs::remove_file(path).ok();
}

#[test]
fn oversized_section_length_fails_before_allocating() {
    // section length fields sit outside the CRCs; a flipped high bit
    // must fail cleanly against the file-size bound, not attempt a
    // multi-GiB allocation
    let sd = demo_dict(OptKind::AdamW, Variant::Flash, 3);
    let path = tmp("biglen");
    checkpoint::save_state_dict(&path, &sd).unwrap();
    let clean = std::fs::read(&path).unwrap();
    let (_, payload_off, _) = v2_regions(&clean)
        .into_iter()
        .find(|(label, _, _)| label.contains("section"))
        .unwrap();
    let len_off = payload_off - 8; // u64 length precedes the payload
    let mut bytes = clean.clone();
    bytes[len_off + 3] |= 0x10; // += 256 MiB: < the 16 GiB cap, > file
    std::fs::write(&path, &bytes).unwrap();
    let err = checkpoint::load_state_dict(&path).unwrap_err().to_string();
    assert!(err.contains("exceeds file size"), "{err}");
    std::fs::remove_file(path).ok();
}

#[test]
fn v2_truncation_detected() {
    let sd = demo_dict(OptKind::Lion, Variant::Flash, 5);
    let path = tmp("trunc");
    checkpoint::save_state_dict(&path, &sd).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    for cut in [bytes.len() - 1, bytes.len() / 2, 40, 11] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(checkpoint::load_state_dict(&path).is_err(), "cut={cut}");
    }
    std::fs::remove_file(path).ok();
}
