//! Seeded differential fuzzer for the fused single-pass step kernels.
//!
//! Every case draws a random (optimizer, variant, partition length,
//! hyper vector, step count) tuple plus adversarial injections
//! (NaN / Inf / denormal / saturating gradients and weights,
//! NaN-producing hypers), then drives the same trajectory through
//! three independent implementations:
//!
//! * `scalar_ref::step_state` — the legacy whole-buffer mirror;
//! * the **tiled** three-pass `step_part` path (`fused_step = false`);
//! * the **fused** register-resident single-pass path
//!   (`fused_step = true`);
//!
//! for every kernel set the CPU supports (`scalar` always, `avx2` when
//! detected), asserting bit-exact agreement of every state buffer
//! after every step.  A quarter of the cases additionally run the
//! fused path on the thread-parallel backend.
//!
//! Determinism: the case stream derives from one seed
//! (`FUSED_FUZZ_SEED`, default `0xF5ED`), so a CI failure names a case
//! index that replays locally with the same env.  The case budget is
//! env-tunable (`FUSED_FUZZ_CASES`, default 48) so CI runs a fixed,
//! attributable budget (see .github/workflows/ci.yml).

use flashtrain::backend::fused::TILE;
use flashtrain::backend::{ParallelBackend, ScalarBackend, StepBackend};
use flashtrain::config::{KernelKind, OptKind, TrainConfig, Variant};
use flashtrain::formats::{bf16, GROUP};
use flashtrain::kernels::avx2_available;
use flashtrain::optim::{scalar_ref, Hyper, State};
use flashtrain::util::rng::Rng;

const ALL_OPTS: [OptKind; 3] =
    [OptKind::Sgd, OptKind::AdamW, OptKind::Lion];
const ALL_VARIANTS: [Variant; 5] = [
    Variant::Reference,
    Variant::Flash,
    Variant::WeightSplit,
    Variant::OptQuant,
    Variant::NoCompand,
];

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Ok(v) => v.parse().unwrap_or_else(|_| {
            panic!("{name} must be an integer, got {v:?}")
        }),
        Err(_) => default,
    }
}

/// Which adversarial injections this case applies.
#[derive(Clone, Copy, Debug)]
struct Inject {
    nan: bool,
    inf: bool,
    denormal: bool,
    saturating: bool,
}

impl Inject {
    fn draw(rng: &mut Rng) -> Inject {
        Inject {
            nan: rng.below(4) == 0,
            inf: rng.below(4) == 0,
            denormal: rng.below(4) == 0,
            saturating: rng.below(4) == 0,
        }
    }
}

/// Heavy-tailed value across many binades.
fn heavy(rng: &mut Rng) -> f32 {
    let mag = (rng.f32() * 40.0 - 30.0).exp2();
    let sign = if rng.f32() < 0.5 { -1.0 } else { 1.0 };
    sign * mag * (0.5 + rng.f32())
}

fn sprinkle(rng: &mut Rng, buf: &mut [f32], count: usize,
            mut val: impl FnMut(&mut Rng) -> f32) {
    for _ in 0..count {
        let i = rng.below(buf.len() as u64) as usize;
        buf[i] = val(rng);
    }
}

fn gen_values(rng: &mut Rng, n: usize, scale: f32, inj: Inject)
              -> Vec<f32> {
    let mut v: Vec<f32> = (0..n).map(|_| heavy(rng) * scale).collect();
    let k = n / 16 + 1;
    if inj.nan {
        // quiet NaNs with payloads plus one signaling NaN (the bf16 /
        // split codecs quiet it deterministically)
        sprinkle(rng, &mut v, k, |r| {
            f32::from_bits(0x7FC0_0000 | (r.u64() as u32 & 0x003F_FFFF))
        });
        let i = rng.below(n as u64) as usize;
        v[i] = f32::from_bits(0x7F80_0001);
    }
    if inj.inf {
        sprinkle(rng, &mut v, k, |r| {
            if r.below(2) == 0 { f32::INFINITY } else { f32::NEG_INFINITY }
        });
    }
    if inj.denormal {
        sprinkle(rng, &mut v, k, |r| {
            f32::from_bits(1 + (r.u64() as u32 & 0x007F_FFFE))
        });
    }
    if inj.saturating {
        // magnitudes whose group absmax saturates the f16 scale
        sprinkle(rng, &mut v, k, |r| {
            if r.below(2) == 0 { 1e30 } else { -1e30 }
        });
    }
    v
}

/// Gradient in the variant's dtype semantics (bf16 for split tracks).
fn gen_grad(rng: &mut Rng, n: usize, variant: Variant, inj: Inject)
            -> Vec<f32> {
    let mut g = gen_values(rng, n, 0.01, inj);
    if variant.splits_weights() {
        for x in g.iter_mut() {
            *x = bf16::round_f32_to_bf16(*x);
        }
    }
    g
}

/// Random hypers; occasionally adversarial ones that force NaN or
/// saturation through the update itself (negative beta2 drives the
/// variance negative -> sqrt NaN; eps = 0 allows 0/0; lr = 1e30
/// saturates the split-weight range).
///
/// One deliberate carve-out: with NaN injection on, `wd` is kept
/// nonzero.  A NaN gradient meeting `wd = 0` at a ±inf (non-NaN)
/// weight makes *both* operands of the update's `div + wd*θ` add NaN
/// with distinct payloads, and IEEE-754 leaves which payload survives
/// a two-NaN add to the implementation (LLVM may commute the scalar
/// add; the vector kernel fixes operand order).  Note a NaN *θ* is
/// fine and stays in the injection space: it also produces a two-NaN
/// add, but the ambiguous result only feeds the final non-commutable
/// `θ − lr·term` subtraction, which selects θ's payload on both
/// encodings (and NaN moments requantize to code 0 regardless), so
/// nothing implementation-chosen reaches stored state.  Everywhere
/// else — NaN weights, NaN gradients with decay, inf/inf and 0/0
/// defaults — the surviving payload is forced by the algebra and is
/// asserted bit-exactly.
fn gen_hyper(rng: &mut Rng, opt: OptKind, inj: Inject) -> Hyper {
    let wd = if inj.nan {
        0.05 + rng.f64() * 0.15
    } else if rng.below(2) == 0 {
        0.0
    } else {
        rng.f64() * 0.2
    };
    let cfg = TrainConfig {
        optimizer: opt,
        beta1: 0.5 + rng.f64() * 0.49,
        beta2: 0.8 + rng.f64() * 0.199,
        eps: 1e-8,
        weight_decay: wd,
        ..Default::default()
    };
    let t = 1 + rng.below(2000) as usize;
    let lr = 1e-4 + rng.f64() * 5e-3;
    let mut h = Hyper::for_step(&cfg, lr, t);
    if rng.below(4) == 0 {
        match rng.below(3) {
            0 => h.beta2 = -0.5,
            1 => h.lr = 1e30,
            _ => h.eps = 0.0,
        }
    }
    h
}

/// Partition length in elements: short tails, just-past-a-tile, and
/// multi-tile-crossing lengths (all GROUP-aligned, as the step-range
/// contract requires).
fn gen_len(rng: &mut Rng) -> usize {
    let tile_groups = (TILE / GROUP) as u64;
    let groups = match rng.below(4) {
        0 => 1 + rng.below(4),
        1 => tile_groups + rng.below(3),
        2 => 2 * tile_groups + 1 + rng.below(tile_groups),
        _ => 1 + rng.below(48),
    };
    groups as usize * GROUP
}

fn assert_states_bit_equal(a: &State, b: &State, what: &str) {
    assert_eq!(a.theta_p, b.theta_p, "{what}: theta_p");
    assert_eq!(a.rho, b.rho, "{what}: rho");
    assert_eq!(a.mq, b.mq, "{what}: mq");
    assert_eq!(a.ms, b.ms, "{what}: ms");
    assert_eq!(a.vq, b.vq, "{what}: vq");
    assert_eq!(a.vs, b.vs, "{what}: vs");
    for (name, x, y) in [("theta", &a.theta, &b.theta),
                         ("m", &a.m, &b.m), ("v", &a.v, &b.v)] {
        match (x, y) {
            (Some(x), Some(y)) => {
                for (i, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "{what}: {name}[{i}] {p:?} \
                                ({:#010x}) vs {q:?} ({:#010x})",
                               p.to_bits(), q.to_bits());
                }
            }
            (None, None) => {}
            _ => panic!("{what}: {name} presence differs"),
        }
    }
}

#[test]
fn fused_vs_tiled_vs_scalar_ref_differential_fuzz() {
    let cases = env_u64("FUSED_FUZZ_CASES", 48) as usize;
    let seed = env_u64("FUSED_FUZZ_SEED", 0xF5ED);
    let mut kinds = vec![KernelKind::Scalar];
    if avx2_available() {
        kinds.push(KernelKind::Avx2);
    } else {
        eprintln!("note: AVX2 not available; fuzzing the portable set \
                   only");
    }
    let mut rng = Rng::new(seed);
    let mut covered = 0usize;
    let mut pairs_seen = std::collections::BTreeSet::new();

    for case in 0..cases {
        let opt = ALL_OPTS[rng.below(3) as usize];
        let variant = ALL_VARIANTS[rng.below(5) as usize];
        pairs_seen.insert((opt.name(), variant.name()));
        let n = gen_len(&mut rng);
        let steps = 1 + rng.below(4) as usize;
        let inj = Inject::draw(&mut rng);
        let theta0 = gen_values(&mut rng, n, 0.1, inj);
        let ctx = format!(
            "case {case} (seed {seed}): {opt}/{variant} n={n} \
             steps={steps} {inj:?}");

        // one backend pair per kernel set, shared across the trajectory
        let engines: Vec<(KernelKind, ScalarBackend, ScalarBackend)> =
            kinds
                .iter()
                .map(|&k| {
                    (k,
                     ScalarBackend::with_options(k, false).unwrap(),
                     ScalarBackend::with_options(k, true).unwrap())
                })
                .collect();
        let par = if case % 4 == 0 {
            Some(ParallelBackend::with_options(
                1 + rng.below(4) as usize, KernelKind::Auto, true)
                .unwrap())
        } else {
            None
        };

        let mut legacy = State::init(&theta0, n, opt, variant);
        let mut tiled: Vec<State> =
            engines.iter().map(|_| legacy.clone()).collect();
        let mut fused: Vec<State> =
            engines.iter().map(|_| legacy.clone()).collect();
        let mut par_st = par.as_ref().map(|_| legacy.clone());

        if flashtrain::kernels::kernel_set(KernelKind::Scalar)
            .unwrap()
            .fused_step(opt, variant)
            .is_some()
        {
            covered += 1;
        }

        for t in 1..=steps {
            let h = gen_hyper(&mut rng, opt, inj);
            let g = gen_grad(&mut rng, n, variant, inj);
            scalar_ref::step_state(&mut legacy, &g, opt, variant, &h);
            for (i, (k, tiled_be, fused_be)) in
                engines.iter().enumerate()
            {
                tiled_be
                    .step_full(&mut tiled[i], &g, opt, variant, &h)
                    .unwrap();
                fused_be
                    .step_full(&mut fused[i], &g, opt, variant, &h)
                    .unwrap();
                assert_states_bit_equal(
                    &legacy, &tiled[i],
                    &format!("{ctx} step {t} tiled[{k}]"));
                assert_states_bit_equal(
                    &legacy, &fused[i],
                    &format!("{ctx} step {t} fused[{k}]"));
            }
            if let (Some(par), Some(st)) = (&par, par_st.as_mut()) {
                par.step_full(st, &g, opt, variant, &h).unwrap();
                assert_states_bit_equal(
                    &legacy, st, &format!("{ctx} step {t} parallel"));
            }
        }
    }
    // coverage guards over the *actual* case stream: a distribution
    // change (or a collapsed draw) must fail loudly rather than
    // silently shrinking what the budget fuzzes.  48 uniform draws
    // over 15 cells miss ~0.6 cells in expectation; a floor of 8
    // distinct pairs is orders of magnitude below any plausible
    // healthy draw while still catching a constant-pair collapse.
    assert!(cases < 8 || covered > 0,
            "no fused-covered pair drawn in {cases} cases");
    assert!(cases < 48 || pairs_seen.len() >= 8,
            "only {} of 15 (optimizer, variant) pairs drawn in {cases} \
             cases",
            pairs_seen.len());
    println!(
        "fused_fuzz: {cases} cases OK (seed {seed}, {} kernel sets, \
         {} pairs, {covered} fused-covered)",
        kinds.len(), pairs_seen.len());
}
