//! Seeded differential fuzzer for the fused single-pass step kernels.
//!
//! Every case draws a random (optimizer, variant, partition length,
//! hyper vector, step count) tuple plus adversarial injections
//! (NaN / Inf / denormal / saturating gradients and weights,
//! NaN-producing hypers), then drives the same trajectory through
//! three independent implementations:
//!
//! * `scalar_ref::step_state` — the legacy whole-buffer mirror;
//! * the **tiled** three-pass `step_part` path (`fused_step = false`);
//! * the **fused** register-resident single-pass path
//!   (`fused_step = true`);
//!
//! for every kernel set the CPU supports (`scalar` always, `avx2` when
//! detected), asserting bit-exact agreement of every state buffer
//! after every step.  A quarter of the cases additionally run the
//! fused path on the thread-parallel backend.
//!
//! Pair coverage is the **full 21-pair universe** (3 optimizers × 7
//! variants — the fused kernels cover every pair since the
//! fp32-resident layouts fused, the nibble-packed `quant4`/`mixed84`
//! layouts included): the first 21 cases enumerate the
//! pairs round-robin so every pair is *deterministically* exercised
//! through fused, tiled, and scalar mirrors whenever the budget allows
//! it, and the remaining budget draws pairs uniformly.  A distribution
//! change that silently drops a pair fails the coverage assertion at
//! the end of the run, loudly.
//!
//! A second leg (`streaming_vs_batch_differential_fuzz`) drives the
//! gradient-release streaming step against the batch step at the
//! `FlashOptimizer` level: random bucket sizes (including non-GROUP
//! tails), random out-of-order bucket arrival, unaligned parameter
//! counts, multi-group splits and 1–4 steps under the same injection
//! machinery, asserting a bit-exact final state — the paper's
//! 5-bytes/param mode must never buy its memory back with drift.  Its
//! deterministic prefix covers streaming on all 21 pairs.
//!
//! A third leg (`sharded_vs_batch_differential_fuzz`) turns on
//! shard-owner execution (`shard_state`) and drives it against the
//! plain batch step under the same machinery: random thread counts,
//! batch and streaming (out-of-order) sharded steps, multi-group
//! splits, unaligned counts/buckets, plus the sequential no-op
//! fallback — the stable owner partition and the fused shard-local
//! reduce must be invisible in the bits.  Its deterministic prefix
//! covers sharding on all 21 pairs.
//!
//! Determinism: the case stream derives from one seed
//! (`FUSED_FUZZ_SEED`, default `0xF5ED`), so a CI failure names a case
//! index that replays locally with the same env.  The case budget is
//! env-tunable (`FUSED_FUZZ_CASES`, default 48); PR CI runs a fixed
//! seed/budget step and the nightly `deep-fuzz` workflow runs a
//! run-id-derived seed at `FUSED_FUZZ_CASES=4096`, printing the exact
//! repro line (see .github/workflows/{ci,nightly-deep-fuzz}.yml).

use flashtrain::backend::fused::TILE;
use flashtrain::backend::{ParallelBackend, ScalarBackend, StepBackend};
use flashtrain::config::{BackendKind, KernelKind, OptKind, TrainConfig,
                         Variant};
use flashtrain::formats::{bf16, GROUP};
use flashtrain::kernels::avx2_available;
use flashtrain::optim::{scalar_ref, FlashOptimizer, GroupHyper,
                        GroupSpec, Hyper, HyperDefaults, State};
use flashtrain::util::rng::Rng;

const ALL_OPTS: [OptKind; 3] =
    [OptKind::Sgd, OptKind::AdamW, OptKind::Lion];
const ALL_VARIANTS: [Variant; 7] = [
    Variant::Reference,
    Variant::Flash,
    Variant::WeightSplit,
    Variant::OptQuant,
    Variant::NoCompand,
    Variant::Quant4,
    Variant::Mixed84,
];

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Ok(v) => v.parse().unwrap_or_else(|_| {
            panic!("{name} must be an integer, got {v:?}")
        }),
        Err(_) => default,
    }
}

/// Which adversarial injections this case applies.
#[derive(Clone, Copy, Debug)]
struct Inject {
    nan: bool,
    inf: bool,
    denormal: bool,
    saturating: bool,
    /// Inject only the canonical quiet NaN (0x7FC00000), no payload
    /// diversity and no sNaN — set for layouts whose moments live in
    /// fp32 (see [`Inject::constrain_for`]).
    canonical_nan: bool,
}

impl Inject {
    fn draw(rng: &mut Rng) -> Inject {
        Inject {
            nan: rng.below(4) == 0,
            inf: rng.below(4) == 0,
            denormal: rng.below(4) == 0,
            saturating: rng.below(4) == 0,
            canonical_nan: false,
        }
    }

    /// Layout-aware carve-out (mirrors the NaN-flow analysis in
    /// `kernels/avx2.rs`): for layouts that keep their moments in fp32
    /// (`reference`, `wsplit`), a NaN moment persists across steps
    /// instead of requantizing to code 0, so the moment update
    /// `β·m + (1−β)·g` can see two NaN operands.  IEEE-754 leaves a
    /// two-NaN add's surviving payload to the implementation (and LLVM
    /// may commute the scalar fadd), so the add is deterministic only
    /// when both NaN operands carry identical bits.  NaN-injecting
    /// cases on these layouts therefore (a) inject only the canonical
    /// quiet NaN, and (b) drop ±inf / f16-saturating magnitudes — the
    /// only routes to the *other* NaN bit pattern, the 0xFFC00000
    /// hardware default from ∞−∞ / 0·∞ / inf-driven corners — so every
    /// NaN in such a case is the same value and every two-NaN add is
    /// unambiguous.  The caller also skips the NaN-manufacturing hyper
    /// mutations for these cases (same reasoning: sqrt(-v) and huge-lr
    /// overflow mint 0xFFC00000 / ±inf).  Quantized-moment layouts
    /// keep the full injection space (their dequantized moments are
    /// always finite, so the moment update never sees two NaNs; the
    /// one excluded corner there is wd = 0, handled in `gen_hyper`).
    fn constrain_for(mut self, variant: Variant) -> Inject {
        let fp32_moments = !variant.quantizes_state();
        if fp32_moments && self.nan {
            self.canonical_nan = true;
            self.inf = false;
            self.saturating = false;
        }
        self
    }

    /// True when this case must also keep the hyper vector free of
    /// NaN-manufacturing mutations (see [`Inject::constrain_for`]).
    fn benign_hypers(&self) -> bool {
        self.canonical_nan && self.nan
    }
}

/// Heavy-tailed value across many binades.
fn heavy(rng: &mut Rng) -> f32 {
    let mag = (rng.f32() * 40.0 - 30.0).exp2();
    let sign = if rng.f32() < 0.5 { -1.0 } else { 1.0 };
    sign * mag * (0.5 + rng.f32())
}

fn sprinkle(rng: &mut Rng, buf: &mut [f32], count: usize,
            mut val: impl FnMut(&mut Rng) -> f32) {
    for _ in 0..count {
        let i = rng.below(buf.len() as u64) as usize;
        buf[i] = val(rng);
    }
}

fn gen_values(rng: &mut Rng, n: usize, scale: f32, inj: Inject)
              -> Vec<f32> {
    let mut v: Vec<f32> = (0..n).map(|_| heavy(rng) * scale).collect();
    let k = n / 16 + 1;
    if inj.nan && inj.canonical_nan {
        // fp32-resident-moment layouts: one NaN value only, so every
        // two-NaN add sees identical operand bits (see constrain_for)
        sprinkle(rng, &mut v, k, |_| f32::from_bits(0x7FC0_0000));
    } else if inj.nan {
        // quiet NaNs with payloads plus one signaling NaN (the bf16 /
        // split codecs quiet it deterministically)
        sprinkle(rng, &mut v, k, |r| {
            f32::from_bits(0x7FC0_0000 | (r.u64() as u32 & 0x003F_FFFF))
        });
        let i = rng.below(n as u64) as usize;
        v[i] = f32::from_bits(0x7F80_0001);
    }
    if inj.inf {
        sprinkle(rng, &mut v, k, |r| {
            if r.below(2) == 0 { f32::INFINITY } else { f32::NEG_INFINITY }
        });
    }
    if inj.denormal {
        sprinkle(rng, &mut v, k, |r| {
            f32::from_bits(1 + (r.u64() as u32 & 0x007F_FFFE))
        });
    }
    if inj.saturating {
        // magnitudes whose group absmax saturates the f16 scale
        sprinkle(rng, &mut v, k, |r| {
            if r.below(2) == 0 { 1e30 } else { -1e30 }
        });
    }
    v
}

/// Gradient in the variant's dtype semantics (bf16 for split tracks).
fn gen_grad(rng: &mut Rng, n: usize, variant: Variant, inj: Inject)
            -> Vec<f32> {
    let mut g = gen_values(rng, n, 0.01, inj);
    if variant.splits_weights() {
        for x in g.iter_mut() {
            *x = bf16::round_f32_to_bf16(*x);
        }
    }
    g
}

/// Random hypers; occasionally adversarial ones that force NaN or
/// saturation through the update itself (negative beta2 drives the
/// variance negative -> sqrt NaN; eps = 0 allows 0/0; lr = 1e30
/// saturates the split-weight range).
///
/// One deliberate carve-out: with NaN injection on, `wd` is kept
/// nonzero.  A NaN gradient meeting `wd = 0` at a ±inf (non-NaN)
/// weight makes *both* operands of the update's `div + wd*θ` add NaN
/// with distinct payloads, and IEEE-754 leaves which payload survives
/// a two-NaN add to the implementation (LLVM may commute the scalar
/// add; the vector kernel fixes operand order).  Note a NaN *θ* is
/// fine and stays in the injection space: it also produces a two-NaN
/// add, but the ambiguous result only feeds the final non-commutable
/// `θ − lr·term` subtraction, which selects θ's payload on both
/// encodings (and NaN moments requantize to code 0 regardless —
/// while fp32-resident NaN θ propagates its *own* payload, also
/// deterministically), so nothing implementation-chosen reaches
/// stored state.  Everywhere else — NaN weights, NaN gradients with
/// decay, inf/inf and 0/0 defaults — the surviving payload is forced
/// by the algebra and is asserted bit-exactly.
///
/// Second carve-out (`Inject::benign_hypers`, fp32-resident-moment
/// layouts with NaN injection): the NaN-manufacturing mutations below
/// are skipped, because mixing their 0xFFC00000 default NaNs / ±inf
/// with the injected canonical NaN would put two *different* NaN
/// payloads into the persistent-fp32 moment update's add — the one
/// spot where IEEE-754 underdetermination would become stored state.
/// The betas drawn here are always strictly inside (0, 1), so no
/// `0·∞` can arise from the moment coefficients themselves.
fn gen_hyper(rng: &mut Rng, opt: OptKind, inj: Inject) -> Hyper {
    let wd = if inj.nan {
        0.05 + rng.f64() * 0.15
    } else if rng.below(2) == 0 {
        0.0
    } else {
        rng.f64() * 0.2
    };
    let cfg = TrainConfig {
        optimizer: opt,
        beta1: 0.5 + rng.f64() * 0.49,
        beta2: 0.8 + rng.f64() * 0.199,
        eps: 1e-8,
        weight_decay: wd,
        ..Default::default()
    };
    let t = 1 + rng.below(2000) as usize;
    let lr = 1e-4 + rng.f64() * 5e-3;
    let mut h = Hyper::for_step(&cfg, lr, t);
    if rng.below(4) == 0 && !inj.benign_hypers() {
        match rng.below(3) {
            0 => h.beta2 = -0.5,
            1 => h.lr = 1e30,
            _ => h.eps = 0.0,
        }
    }
    h
}

/// Partition length in elements: short tails, just-past-a-tile, and
/// multi-tile-crossing lengths (all GROUP-aligned, as the step-range
/// contract requires).
fn gen_len(rng: &mut Rng) -> usize {
    let tile_groups = (TILE / GROUP) as u64;
    let groups = match rng.below(4) {
        0 => 1 + rng.below(4),
        1 => tile_groups + rng.below(3),
        2 => 2 * tile_groups + 1 + rng.below(tile_groups),
        _ => 1 + rng.below(48),
    };
    groups as usize * GROUP
}

fn assert_states_bit_equal(a: &State, b: &State, what: &str) {
    assert_eq!(a.theta_p, b.theta_p, "{what}: theta_p");
    assert_eq!(a.rho, b.rho, "{what}: rho");
    assert_eq!(a.mq, b.mq, "{what}: mq");
    assert_eq!(a.ms, b.ms, "{what}: ms");
    assert_eq!(a.vq, b.vq, "{what}: vq");
    assert_eq!(a.vs, b.vs, "{what}: vs");
    assert_eq!(a.mq4, b.mq4, "{what}: mq4");
    assert_eq!(a.vq4, b.vq4, "{what}: vq4");
    for (name, x, y) in [("theta", &a.theta, &b.theta),
                         ("m", &a.m, &b.m), ("v", &a.v, &b.v)] {
        match (x, y) {
            (Some(x), Some(y)) => {
                for (i, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "{what}: {name}[{i}] {p:?} \
                                ({:#010x}) vs {q:?} ({:#010x})",
                               p.to_bits(), q.to_bits());
                }
            }
            (None, None) => {}
            _ => panic!("{what}: {name} presence differs"),
        }
    }
}

#[test]
fn fused_vs_tiled_vs_scalar_ref_differential_fuzz() {
    let cases = env_u64("FUSED_FUZZ_CASES", 48) as usize;
    let seed = env_u64("FUSED_FUZZ_SEED", 0xF5ED);
    let mut kinds = vec![KernelKind::Scalar];
    if avx2_available() {
        kinds.push(KernelKind::Avx2);
    } else {
        eprintln!("note: AVX2 not available; fuzzing the portable set \
                   only");
    }
    let mut rng = Rng::new(seed);
    let universe: Vec<(OptKind, Variant)> = ALL_OPTS
        .iter()
        .flat_map(|&o| ALL_VARIANTS.iter().map(move |&v| (o, v)))
        .collect();
    assert_eq!(universe.len(), 21);
    // every pair resolves a fused kernel on every supported set: the
    // typed binding means a future regression of `fused_step` back to
    // an Option return (the silent-fallback shape) stops this test
    // COMPILING, not just changes behavior
    for &k in &kinds {
        let ks = flashtrain::kernels::kernel_set(k).unwrap();
        for &(o, v) in &universe {
            let _kernel: flashtrain::kernels::FusedStepFn =
                ks.fused_step(o, v);
        }
    }
    let mut pairs_seen = std::collections::BTreeSet::new();

    for case in 0..cases {
        // first 21 cases: deterministic round-robin over the full
        // 21-pair universe, so coverage never depends on the draw;
        // the rest of the budget samples uniformly
        let (opt, variant) = if case < universe.len() {
            universe[case]
        } else {
            (ALL_OPTS[rng.below(3) as usize],
             ALL_VARIANTS[rng.below(7) as usize])
        };
        pairs_seen.insert((opt.name(), variant.name()));
        let n = gen_len(&mut rng);
        let steps = 1 + rng.below(4) as usize;
        let inj = Inject::draw(&mut rng).constrain_for(variant);
        let theta0 = gen_values(&mut rng, n, 0.1, inj);
        let ctx = format!(
            "case {case} (seed {seed}): {opt}/{variant} n={n} \
             steps={steps} {inj:?}");

        // one backend pair per kernel set, shared across the trajectory
        let engines: Vec<(KernelKind, ScalarBackend, ScalarBackend)> =
            kinds
                .iter()
                .map(|&k| {
                    (k,
                     ScalarBackend::with_options(k, false).unwrap(),
                     ScalarBackend::with_options(k, true).unwrap())
                })
                .collect();
        let par = if case % 4 == 0 {
            Some(ParallelBackend::with_options(
                1 + rng.below(4) as usize, KernelKind::Auto, true)
                .unwrap())
        } else {
            None
        };

        let mut legacy = State::init(&theta0, n, opt, variant);
        let mut tiled: Vec<State> =
            engines.iter().map(|_| legacy.clone()).collect();
        let mut fused: Vec<State> =
            engines.iter().map(|_| legacy.clone()).collect();
        let mut par_st = par.as_ref().map(|_| legacy.clone());

        for t in 1..=steps {
            let h = gen_hyper(&mut rng, opt, inj);
            let g = gen_grad(&mut rng, n, variant, inj);
            scalar_ref::step_state(&mut legacy, &g, opt, variant, &h);
            for (i, (k, tiled_be, fused_be)) in
                engines.iter().enumerate()
            {
                tiled_be
                    .step_full(&mut tiled[i], &g, opt, variant, &h)
                    .unwrap();
                fused_be
                    .step_full(&mut fused[i], &g, opt, variant, &h)
                    .unwrap();
                assert_states_bit_equal(
                    &legacy, &tiled[i],
                    &format!("{ctx} step {t} tiled[{k}]"));
                assert_states_bit_equal(
                    &legacy, &fused[i],
                    &format!("{ctx} step {t} fused[{k}]"));
            }
            if let (Some(par), Some(st)) = (&par, par_st.as_mut()) {
                par.step_full(st, &g, opt, variant, &h).unwrap();
                assert_states_bit_equal(
                    &legacy, st, &format!("{ctx} step {t} parallel"));
            }
        }
    }
    // coverage guard over the *actual* case stream: the round-robin
    // prefix makes full 21-pair coverage deterministic for any budget
    // of at least 21 cases, so anything short of the complete universe
    // is a loud failure, not a silently shrunk fuzz surface
    assert!(cases < universe.len()
                || pairs_seen.len() == universe.len(),
            "only {} of {} (optimizer, variant) pairs exercised in \
             {cases} cases — the deterministic round-robin prefix \
             should have covered every pair",
            pairs_seen.len(), universe.len());
    println!(
        "fused_fuzz: {cases} cases OK (seed {seed}, {} kernel sets, \
         {}/21 pairs, all fused-covered)",
        kinds.len(), pairs_seen.len());
}

#[test]
fn streaming_vs_batch_differential_fuzz() {
    let cases = env_u64("FUSED_FUZZ_CASES", 48) as usize;
    let seed = env_u64("FUSED_FUZZ_SEED", 0xF5ED) ^ 0x57_EA11;
    let mut rng = Rng::new(seed);
    let universe: Vec<(OptKind, Variant)> = ALL_OPTS
        .iter()
        .flat_map(|&o| ALL_VARIANTS.iter().map(move |&v| (o, v)))
        .collect();
    let mut pairs_seen = std::collections::BTreeSet::new();

    for case in 0..cases {
        // same deterministic-prefix scheme as the fused leg: the first
        // 21 cases cover streaming on every (optimizer, variant) pair
        let (opt, variant) = if case < universe.len() {
            universe[case]
        } else {
            (ALL_OPTS[rng.below(3) as usize],
             ALL_VARIANTS[rng.below(7) as usize])
        };
        pairs_seen.insert((opt.name(), variant.name()));
        let steps = 1 + rng.below(4) as usize;
        let inj = Inject::draw(&mut rng).constrain_for(variant);
        // real parameter count: usually a non-GROUP tail
        let count =
            (gen_len(&mut rng) - rng.below(GROUP as u64) as usize).max(1);
        // bucket size: GROUP-aligned or deliberately unaligned, so the
        // stream must hold and coalesce partial-group edges
        let bucket = match rng.below(3) {
            0 => GROUP * (1 + rng.below(3) as usize),
            1 => 100,
            _ => GROUP + 1 + rng.below(2 * GROUP as u64) as usize,
        };

        // random hypers through the defaults-resolution path both
        // modes share, with the same NaN carve-outs as gen_hyper
        // (nonzero wd under NaN injection; no NaN-manufacturing
        // mutations for fp32-resident-moment layouts)
        let wd = if inj.nan {
            0.05 + rng.f64() * 0.15
        } else if rng.below(2) == 0 {
            0.0
        } else {
            rng.f64() * 0.2
        };
        let mut cfg = TrainConfig {
            optimizer: opt,
            beta1: 0.5 + rng.f64() * 0.49,
            beta2: 0.8 + rng.f64() * 0.199,
            eps: 1e-8,
            weight_decay: wd,
            ..Default::default()
        };
        if rng.below(4) == 0 && !inj.benign_hypers() {
            match rng.below(2) {
                0 => cfg.beta2 = -0.5,
                _ => cfg.eps = 0.0,
            }
        }
        let lr = if rng.below(8) == 0 && !inj.benign_hypers() {
            1e30
        } else {
            1e-4 + rng.f64() * 5e-3
        };
        let t_base = rng.below(2000) as usize;

        let theta0 = gen_values(&mut rng, count, 0.1, inj);
        let specs = if case % 3 == 0 && count >= 2 {
            // multi-group split with per-group overrides (wd only when
            // the NaN carve-out allows zero decay)
            let s = 1 + rng.below(count as u64 - 1) as usize;
            let mut h2 = GroupHyper {
                lr_scale: Some(0.5),
                ..GroupHyper::default()
            };
            if !inj.nan {
                h2.weight_decay = Some(0.0);
            }
            vec![GroupSpec {
                     name: "head".into(),
                     ranges: vec![(0, s)],
                     hyper: GroupHyper::default(),
                 },
                 GroupSpec {
                     name: "body".into(),
                     ranges: vec![(s, count)],
                     hyper: h2,
                 }]
        } else {
            GroupSpec::single(count)
        };
        let (backend, threads) = if case % 4 == 0 {
            (BackendKind::Parallel, 1 + rng.below(4) as usize)
        } else {
            (BackendKind::Scalar, 0)
        };
        let kernels = if case % 2 == 0 {
            KernelKind::Scalar
        } else {
            KernelKind::Auto
        };
        let fused = case % 3 != 1; // in-test tiled-mirror coverage
        let ctx = format!(
            "streaming case {case} (seed {seed}): {opt}/{variant} \
             count={count} bucket={bucket} steps={steps} \
             groups={} {backend:?}x{threads} {inj:?}",
            specs.len());

        let mk = || {
            FlashOptimizer::native_with_opts(
                opt, variant, bucket, &theta0, specs.clone(),
                HyperDefaults::of(&cfg), backend, threads, kernels,
                fused)
                .unwrap()
        };
        let mut batch = mk();
        let mut stream = mk();
        let nb = batch.n_buckets();
        for s in 1..=steps {
            let t = t_base + s;
            let g = gen_grad(&mut rng, count, variant, inj);
            batch.step(&g, lr, t, |_, _| {}).unwrap();
            // random out-of-order bucket arrival (Fisher–Yates)
            let mut order: Vec<usize> = (0..nb).collect();
            for i in (1..order.len()).rev() {
                order.swap(i, rng.below(i as u64 + 1) as usize);
            }
            stream
                .step_streaming_order(&g, lr, t, Some(&order), |_, _| {})
                .unwrap();
            for (ga, gb) in batch.groups.iter().zip(&stream.groups) {
                assert_states_bit_equal(
                    &ga.opt.state, &gb.opt.state,
                    &format!("{ctx} step {s} group {}", ga.name));
            }
        }
        assert_eq!(batch.compute_weights_bf16(count),
                   stream.compute_weights_bf16(count),
                   "{ctx}: compute weights");
    }
    assert!(cases < universe.len()
                || pairs_seen.len() == universe.len(),
            "only {} of {} (optimizer, variant) pairs exercised in \
             {cases} streaming cases — the deterministic round-robin \
             prefix should have covered every pair",
            pairs_seen.len(), universe.len());
    println!(
        "streaming_fuzz: {cases} cases OK (seed {seed}, {}/21 pairs)",
        pairs_seen.len());
}

#[test]
fn sharded_vs_batch_differential_fuzz() {
    let cases = env_u64("FUSED_FUZZ_CASES", 48) as usize;
    let seed = env_u64("FUSED_FUZZ_SEED", 0xF5ED) ^ 0x5A_ADED;
    let mut rng = Rng::new(seed);
    let universe: Vec<(OptKind, Variant)> = ALL_OPTS
        .iter()
        .flat_map(|&o| ALL_VARIANTS.iter().map(move |&v| (o, v)))
        .collect();
    let mut pairs_seen = std::collections::BTreeSet::new();

    for case in 0..cases {
        // same deterministic-prefix scheme as the other legs: the
        // first 21 cases cover sharding on every (optimizer, variant)
        let (opt, variant) = if case < universe.len() {
            universe[case]
        } else {
            (ALL_OPTS[rng.below(3) as usize],
             ALL_VARIANTS[rng.below(7) as usize])
        };
        pairs_seen.insert((opt.name(), variant.name()));
        let steps = 1 + rng.below(4) as usize;
        let inj = Inject::draw(&mut rng).constrain_for(variant);
        let count =
            (gen_len(&mut rng) - rng.below(GROUP as u64) as usize).max(1);
        let bucket = match rng.below(3) {
            0 => GROUP * (1 + rng.below(3) as usize),
            1 => 100,
            _ => GROUP + 1 + rng.below(2 * GROUP as u64) as usize,
        };

        // same hyper scheme and NaN carve-outs as the streaming leg
        let wd = if inj.nan {
            0.05 + rng.f64() * 0.15
        } else if rng.below(2) == 0 {
            0.0
        } else {
            rng.f64() * 0.2
        };
        let mut cfg = TrainConfig {
            optimizer: opt,
            beta1: 0.5 + rng.f64() * 0.49,
            beta2: 0.8 + rng.f64() * 0.199,
            eps: 1e-8,
            weight_decay: wd,
            ..Default::default()
        };
        if rng.below(4) == 0 && !inj.benign_hypers() {
            match rng.below(2) {
                0 => cfg.beta2 = -0.5,
                _ => cfg.eps = 0.0,
            }
        }
        let lr = if rng.below(8) == 0 && !inj.benign_hypers() {
            1e30
        } else {
            1e-4 + rng.f64() * 5e-3
        };
        let t_base = rng.below(2000) as usize;

        let theta0 = gen_values(&mut rng, count, 0.1, inj);
        let specs = if case % 3 == 0 && count >= 2 {
            let s = 1 + rng.below(count as u64 - 1) as usize;
            let mut h2 = GroupHyper {
                lr_scale: Some(0.5),
                ..GroupHyper::default()
            };
            if !inj.nan {
                h2.weight_decay = Some(0.0);
            }
            vec![GroupSpec {
                     name: "head".into(),
                     ranges: vec![(0, s)],
                     hyper: GroupHyper::default(),
                 },
                 GroupSpec {
                     name: "body".into(),
                     ranges: vec![(s, count)],
                     hyper: h2,
                 }]
        } else {
            GroupSpec::single(count)
        };
        // sharding only engages on the pool backend, so most cases run
        // there with a random worker count; every fourth exercises the
        // documented sequential no-op fallback on the scalar backend
        let (backend, threads) = if case % 4 == 3 {
            (BackendKind::Scalar, 0)
        } else {
            (BackendKind::Parallel, 1 + rng.below(8) as usize)
        };
        let kernels = if case % 2 == 0 {
            KernelKind::Scalar
        } else {
            KernelKind::Auto
        };
        let fused = case % 3 != 1; // in-test tiled-mirror coverage
        // half the sharded cases arrive through the streaming path, so
        // shard ownership composes with out-of-order bucket release
        let streaming = case % 2 == 1;
        let ctx = format!(
            "sharded case {case} (seed {seed}): {opt}/{variant} \
             count={count} bucket={bucket} steps={steps} \
             groups={} {backend:?}x{threads} streaming={streaming} \
             {inj:?}",
            specs.len());

        let mk = || {
            FlashOptimizer::native_with_opts(
                opt, variant, bucket, &theta0, specs.clone(),
                HyperDefaults::of(&cfg), backend, threads, kernels,
                fused)
                .unwrap()
        };
        let mut batch = mk();
        let mut shard = mk();
        shard.set_shard_state(true);
        let nb = batch.n_buckets();
        for s in 1..=steps {
            let t = t_base + s;
            let g = gen_grad(&mut rng, count, variant, inj);
            batch.step(&g, lr, t, |_, _| {}).unwrap();
            if streaming {
                // random out-of-order bucket arrival (Fisher–Yates)
                let mut order: Vec<usize> = (0..nb).collect();
                for i in (1..order.len()).rev() {
                    order.swap(i, rng.below(i as u64 + 1) as usize);
                }
                shard
                    .step_streaming_order(&g, lr, t, Some(&order),
                                          |_, _| {})
                    .unwrap();
            } else {
                shard.step(&g, lr, t, |_, _| {}).unwrap();
            }
            for (ga, gb) in batch.groups.iter().zip(&shard.groups) {
                assert_states_bit_equal(
                    &ga.opt.state, &gb.opt.state,
                    &format!("{ctx} step {s} group {}", ga.name));
            }
        }
        assert_eq!(batch.compute_weights_bf16(count),
                   shard.compute_weights_bf16(count),
                   "{ctx}: compute weights");
    }
    assert!(cases < universe.len()
                || pairs_seen.len() == universe.len(),
            "only {} of {} (optimizer, variant) pairs exercised in \
             {cases} sharded cases — the deterministic round-robin \
             prefix should have covered every pair",
            pairs_seen.len(), universe.len());
    println!(
        "sharded_fuzz: {cases} cases OK (seed {seed}, {}/21 pairs)",
        pairs_seen.len());
}
