//! Tier-1 gate for `flashoptim-analyze` (rule catalog in
//! docs/ANALYSIS.md):
//!
//! * `repo_is_clean` runs every rule over the real checkout and fails
//!   on any finding — the same pass the
//!   `cargo run --bin flashoptim-analyze` CLI and both CI matrix legs
//!   run;
//! * one negative test per rule scans a planted fixture
//!   (`tests/fixtures/analyze/`, never compiled) under a
//!   scope-matched synthetic path and asserts the rule fires with
//!   `file:line` diagnostics;
//! * `docs_table_matches_registry` keeps the docs/ANALYSIS.md rule
//!   table cell-for-cell in sync with the registry.

use std::path::Path;

use flashtrain::analyze::rules::rules;
use flashtrain::analyze::{run, Corpus, Finding};

fn repo_root() -> &'static Path {
    // the crate lives at <repo>/rust
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
}

fn findings_for(rule: &str, findings: &[Finding]) -> Vec<Finding> {
    findings.iter().filter(|f| f.rule == rule).cloned().collect()
}

fn render(findings: &[Finding]) -> String {
    findings
        .iter()
        .map(|f| f.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

// ---------------------------------------------------------------------------
// the gate: zero findings over the real tree

#[test]
fn repo_is_clean() {
    let findings = flashtrain::analyze::run_repo(repo_root())
        .expect("reading the repo corpus");
    assert!(
        findings.is_empty(),
        "static analysis found {} violation(s):\n{}",
        findings.len(),
        render(&findings)
    );
}

// ---------------------------------------------------------------------------
// per-rule negative fixtures

#[test]
fn a1_flags_unjustified_unsafe() {
    let c = Corpus::from_sources(vec![(
        "rust/src/fixture_a1.rs",
        include_str!("fixtures/analyze/a1_unsafe_hygiene.rs").into(),
    )]);
    let a1 = findings_for("A1", &run(&c));
    // `bad` and `bad_too` fire; the two justified sites do not
    assert_eq!(a1.len(), 2, "{}", render(&a1));
    assert!(a1.iter().all(|f| f.path == "rust/src/fixture_a1.rs"));
    assert_eq!([a1[0].line, a1[1].line], [15, 22], "{}", render(&a1));
}

#[test]
fn a2_flags_fma_unknown_and_non_rne() {
    let c = Corpus::from_sources(vec![(
        "rust/src/kernels/avx2.rs",
        include_str!("fixtures/analyze/a2_simd_policy.rs").into(),
    )]);
    let a2 = findings_for("A2", &run(&c));
    let count = |needle: &str| {
        a2.iter().filter(|f| f.msg.contains(needle)).count()
    };
    assert_eq!(count("forbidden intrinsic `_mm256_fmadd_ps`"), 1,
               "{}", render(&a2));
    assert_eq!(count("`_mm256_madd_epi16` is not on the audited"), 1,
               "{}", render(&a2));
    assert_eq!(count("non-RNE rounding immediate"), 1, "{}",
               render(&a2));
    assert_eq!(count("not pinned at the call site"), 1, "{}",
               render(&a2));
    // the stray _MM_FROUND_TO_ZERO const also falls off the allowlist
    assert_eq!(a2.len(), 5, "{}", render(&a2));
}

#[test]
fn a3_flags_dropped_pairs_everywhere() {
    let c = Corpus::from_sources(vec![
        (
            "rust/src/kernels/mod.rs",
            include_str!("fixtures/analyze/a3_kernels_mod.rs").into(),
        ),
        (
            "rust/tests/fused_fuzz.rs",
            include_str!("fixtures/analyze/a3_fused_fuzz.rs").into(),
        ),
        (
            "rust/benches/kernel_hotpath.rs",
            include_str!("fixtures/analyze/a3_bench.rs").into(),
        ),
        (
            "rust/tests/backend_equivalence.rs",
            include_str!("fixtures/analyze/a3_sharded.rs").into(),
        ),
    ]);
    let a3 = findings_for("A3", &run(&c));
    let count = |needle: &str| {
        a3.iter().filter(|f| f.msg.contains(needle)).count()
    };
    // fields: (Lion, Quant4) dropped + one unmappable extra
    assert_eq!(count("KernelSet fused fields is missing"), 1, "{}",
               render(&a3));
    assert_eq!(count("does not map to a known"), 1, "{}", render(&a3));
    // match: the same dropped arm
    assert_eq!(count("fused_step match is missing"), 1, "{}",
               render(&a3));
    // fuzz universe frozen at the 15-pair world: Quant4 and Mixed84
    // missing across all 3 optimizers
    assert_eq!(count("ALL_OPTS × ALL_VARIANTS is missing"), 6, "{}",
               render(&a3));
    // bench: the 14 rows the 7-row table never had
    assert_eq!(count("bench STEP_ROWS is missing"), 14, "{}",
               render(&a3));
    // sharded table: (Sgd, Reference) and (Lion, Mixed84) dropped
    assert_eq!(count("sharded SHARDED_PAIRS is missing"), 2, "{}",
               render(&a3));
    assert_eq!(a3.len(), 25, "{}", render(&a3));
}

#[test]
fn a3_is_silent_on_the_real_universe() {
    // the real tree already passes via repo_is_clean; this pins that
    // A3 specifically ran there (an anchor rename would otherwise
    // surface as a confusing missing_anchor finding)
    let findings = flashtrain::analyze::run_repo(repo_root())
        .expect("reading the repo corpus");
    let a3 = findings_for("A3", &findings);
    assert!(a3.is_empty(), "{}", render(&a3));
}

#[test]
fn a4_flags_hot_path_panics_only() {
    let c = Corpus::from_sources(vec![(
        "rust/src/backend/fixture_a4.rs",
        include_str!("fixtures/analyze/a4_panic_policy.rs").into(),
    )]);
    let a4 = findings_for("A4", &run(&c));
    // only the untagged, non-test `.unwrap()` fires; the suppressed
    // `.expect()`, the string literal, and the cfg(test) mod do not
    assert_eq!(a4.len(), 1, "{}", render(&a4));
    assert_eq!(a4[0].line, 5, "{}", render(&a4));
    assert!(a4[0].msg.contains("`.unwrap()`"), "{}", render(&a4));
}

#[test]
fn a4_ignores_out_of_scope_paths() {
    let c = Corpus::from_sources(vec![(
        "rust/src/util/fixture_a4.rs",
        include_str!("fixtures/analyze/a4_panic_policy.rs").into(),
    )]);
    assert!(findings_for("A4", &run(&c)).is_empty());
}

#[test]
fn a5_flags_registry_deps() {
    let c = Corpus::from_sources(vec![(
        "rust/fixture/Cargo.toml",
        include_str!("fixtures/analyze/a5_cargo.toml").into(),
    )]);
    let a5 = findings_for("A5", &run(&c));
    let count = |needle: &str| {
        a5.iter().filter(|f| f.msg.contains(needle)).count()
    };
    // xla from the registry instead of the vendored path shim
    assert_eq!(count("`xla` must be the vendored path shim"), 1, "{}",
               render(&a5));
    // serde inline + criterion table-header, both off the allowlist
    assert_eq!(count("`serde` is outside the offline allowlist"), 1,
               "{}", render(&a5));
    assert_eq!(count("`criterion` is outside the offline allowlist"),
               1, "{}", render(&a5));
    assert_eq!(a5.len(), 3, "{}", render(&a5));
}

#[test]
fn a6_flags_undocumented_and_ghost_keys() {
    let c = Corpus::from_sources(vec![
        (
            "rust/src/config/experiment.rs",
            include_str!("fixtures/analyze/a6_experiment.rs").into(),
        ),
        (
            "docs/CONFIG.md",
            include_str!("fixtures/analyze/a6_config.md").into(),
        ),
    ]);
    let a6 = findings_for("A6", &run(&c));
    assert_eq!(a6.len(), 2, "{}", render(&a6));
    // the struct field absent from the Keys table
    assert_eq!(a6[0].path, "rust/src/config/experiment.rs");
    assert_eq!(a6[0].line, 8, "{}", render(&a6));
    assert!(a6[0].msg.contains("`undocumented_knob` is not documented"),
            "{}", render(&a6));
    // the documented key absent from the struct
    assert_eq!(a6[1].path, "docs/CONFIG.md");
    assert_eq!(a6[1].line, 9, "{}", render(&a6));
    assert!(a6[1]
                .msg
                .contains("`ghost_key`, which is not a `TrainConfig` \
                           field"),
            "{}", render(&a6));
}

#[test]
fn a6_reports_missing_config_md() {
    let c = Corpus::from_sources(vec![(
        "rust/src/config/experiment.rs",
        include_str!("fixtures/analyze/a6_experiment.rs").into(),
    )]);
    let a6 = findings_for("A6", &run(&c));
    assert_eq!(a6.len(), 1, "{}", render(&a6));
    assert!(a6[0].msg.contains("could not locate docs/CONFIG.md"),
            "{}", render(&a6));
}

#[test]
fn a6_is_silent_without_the_config_source() {
    // the other rules' fixture corpora never carry experiment.rs —
    // A6 must not demand docs from them
    let c = Corpus::from_sources(vec![(
        "rust/src/other.rs",
        "pub struct NotConfig {}".into(),
    )]);
    assert!(findings_for("A6", &run(&c)).is_empty());
}

// ---------------------------------------------------------------------------
// docs/ANALYSIS.md stays in sync with the registry

#[test]
fn docs_table_matches_registry() {
    let doc = std::fs::read_to_string(
        repo_root().join("docs/ANALYSIS.md"))
        .expect("docs/ANALYSIS.md exists");
    let mut rows: Vec<(String, String, String)> = Vec::new();
    for line in doc.lines() {
        let cells: Vec<&str> =
            line.split('|').map(str::trim).collect();
        // | id | name | summary | → ["", id, name, summary, ""]
        if cells.len() == 5
            && cells[1].len() == 2
            && cells[1].starts_with('A')
            && cells[1][1..].chars().all(|c| c.is_ascii_digit())
        {
            rows.push((cells[1].into(), cells[2].into(),
                       cells[3].into()));
        }
    }
    let want: Vec<(String, String, String)> = rules()
        .iter()
        .map(|r| {
            (r.id.to_string(), format!("`{}`", r.name),
             r.summary.to_string())
        })
        .collect();
    assert_eq!(
        rows, want,
        "docs/ANALYSIS.md rule table is out of sync with \
         analyze::rules::rules() — regenerate the table from the \
         registry (one `| id | `name` | summary |` row per rule, in \
         order)"
    );
}
