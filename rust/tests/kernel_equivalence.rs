//! Kernel-layer differential suite: every `KernelSet` entry point must
//! be bit-exact to the scalar `formats/` reference.
//!
//! The AVX2 checks run only where the CPU supports AVX2 (a skip note is
//! printed otherwise); the portable set is checked unconditionally,
//! which also pins the function-pointer plumbing itself.
//!
//! Coverage highlights (ISSUE satellite):
//! * exhaustive 2^16-bit-pattern sweeps for the fp16 and bf16 decoders
//!   (every NaN payload, every subnormal, both signed zeros, inf);
//! * encoder sweeps over all values decoded from those patterns, their
//!   ULP-perturbations (tie-rounding neighborhoods), and dense random
//!   floats across binades incl. NaN/inf/subnormals;
//! * adversarial companding groups: all-zero, absmax-saturating
//!   (f16-scale overflow), denormal-scale, and ±tie-rounding values;
//! * exhaustive 2^8 packed nibble-pair byte sweep for the 4-bit
//!   decoders (every (low, high) code combination, signed and
//!   unsigned, under unit/max/subnormal/zero f16 scales), plus the
//!   same adversarial companding groups through the `quant4` /
//!   `mixed84` codecs;
//! * weight-split compress/decompress over random + special values;
//! * fused single-pass step kernels driven through the same
//!   adversarial groups (plus ±inf / NaN weights, NaN/saturating
//!   gradients, and NaN-producing hypers like negative beta2), over
//!   the **full 21-pair (optimizer, variant) universe** — the
//!   fp32-resident layouts `reference`/`wsplit`/`quant` and the
//!   nibble-packed `quant4`/`mixed84` layouts included —
//!   pinned three ways against the tiled path and the legacy scalar
//!   mirror on every kernel set.  (Multi-step NaN determinism for the
//!   fp32-resident-moment layouts holds here because the same
//!   gradient vector repeats each step, so a NaN moment always meets
//!   the NaN gradient it was minted from — identical payload bits;
//!   see the NaN-flow notes in `kernels/avx2.rs` and the fuzzer's
//!   canonical-payload carve-out for the fresh-gradient case.)

use flashtrain::backend::fused::step_part;
use flashtrain::backend::Part;
use flashtrain::config::{KernelKind, OptKind, TrainConfig, Variant};
use flashtrain::formats::{companding, fp16, quant4, weight_split,
                          GROUP};
use flashtrain::kernels::{avx2_available, kernel_set, KernelSet};
use flashtrain::optim::{scalar_ref, Hyper, State};
use flashtrain::util::rng::Rng;

/// Kernel sets to pin against the scalar reference.
fn sets_under_test() -> Vec<&'static KernelSet> {
    let mut v = vec![kernel_set(KernelKind::Scalar).unwrap()];
    if avx2_available() {
        v.push(kernel_set(KernelKind::Avx2).unwrap());
    } else {
        eprintln!(
            "note: AVX2 not available; kernel equivalence covers the \
             portable set only"
        );
    }
    v
}

fn assert_f32_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: len");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{what}[{i}]: {x:?} ({:#010x}) vs {y:?} ({:#010x})",
                   x.to_bits(), y.to_bits());
    }
}

// --- exhaustive 16-bit decoder sweeps ------------------------------------

#[test]
fn f16_to_f32_exhaustive_all_65536_patterns() {
    let src: Vec<u16> = (0..=u16::MAX).collect();
    let mut reference = vec![0f32; src.len()];
    for (d, &s) in reference.iter_mut().zip(&src) {
        *d = fp16::f16_bits_to_f32(s);
    }
    for ks in sets_under_test() {
        let mut out = vec![0f32; src.len()];
        (ks.f16_to_f32)(&src, &mut out);
        assert_f32_bits_eq(&reference, &out,
                           &format!("f16_to_f32[{}]", ks.name));
    }
}

#[test]
fn bf16_to_f32_exhaustive_all_65536_patterns() {
    let src: Vec<u16> = (0..=u16::MAX).collect();
    let mut reference = vec![0f32; src.len()];
    for (d, &s) in reference.iter_mut().zip(&src) {
        *d = flashtrain::formats::bf16::bf16_bits_to_f32(s);
    }
    for ks in sets_under_test() {
        let mut out = vec![0f32; src.len()];
        (ks.bf16_to_f32)(&src, &mut out);
        assert_f32_bits_eq(&reference, &out,
                           &format!("bf16_to_f32[{}]", ks.name));
    }
}

// --- encoder sweeps ------------------------------------------------------

/// Adversarial f32 inputs for the 16-bit encoders: every exactly
/// representable f16 value, its ULP-neighborhood (tie-rounding cases),
/// dense random floats across binades, and specials.
fn encoder_inputs() -> Vec<f32> {
    let mut v = Vec::with_capacity(5 * 65536 + 4096);
    for bits in 0..=u16::MAX {
        let x = fp16::f16_bits_to_f32(bits);
        v.push(x);
        // perturb both ways by one f32 ULP: lands just off the exact
        // value, probing the round-down/round-up boundary
        v.push(f32::from_bits(x.to_bits().wrapping_add(1)));
        v.push(f32::from_bits(x.to_bits().wrapping_sub(1)));
        // exact halfway points between adjacent f16 values (RNE ties)
        let next = fp16::f16_bits_to_f32(bits.wrapping_add(1));
        if x.is_finite() && next.is_finite() {
            v.push(x / 2.0 + next / 2.0);
        }
        // bf16-relevant pattern: same 16 bits as the high half
        v.push(f32::from_bits((bits as u32) << 16));
    }
    let mut rng = Rng::new(0xF16);
    for _ in 0..4096 {
        v.push(f32::from_bits(rng.u64() as u32));
    }
    v.extend_from_slice(&[
        0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN,
        f32::MIN_POSITIVE, f32::MAX, f32::MIN,
        f32::from_bits(1),          // smallest subnormal
        f32::from_bits(0x007F_FFFF), // largest subnormal
        65504.0, 65519.9, 65520.0, // f16 overflow boundary
        2f32.powi(-24), 2f32.powi(-25), 2f32.powi(-26),
        1.0 + 2f32.powi(-11), 1.0 + 3.0 * 2f32.powi(-11),
    ]);
    v
}

#[test]
fn f32_to_f16_matches_scalar_on_adversarial_sweep() {
    let src = encoder_inputs();
    let mut reference = vec![0u16; src.len()];
    for (d, &s) in reference.iter_mut().zip(&src) {
        *d = fp16::f32_to_f16_bits(s);
    }
    for ks in sets_under_test() {
        let mut out = vec![0u16; src.len()];
        (ks.f32_to_f16)(&src, &mut out);
        for (i, (a, b)) in reference.iter().zip(&out).enumerate() {
            assert_eq!(a, b,
                       "f32_to_f16[{}] at {i}: input {:?} ({:#010x}) \
                        -> {a:#06x} vs {b:#06x}",
                       ks.name, src[i], src[i].to_bits());
        }
    }
}

#[test]
fn f32_to_bf16_matches_scalar_on_adversarial_sweep() {
    let src = encoder_inputs();
    let mut reference = vec![0u16; src.len()];
    for (d, &s) in reference.iter_mut().zip(&src) {
        *d = flashtrain::formats::bf16::f32_to_bf16_bits(s);
    }
    for ks in sets_under_test() {
        let mut out = vec![0u16; src.len()];
        (ks.f32_to_bf16)(&src, &mut out);
        for (i, (a, b)) in reference.iter().zip(&out).enumerate() {
            assert_eq!(a, b,
                       "f32_to_bf16[{}] at {i}: input {:?} ({:#010x}) \
                        -> {a:#06x} vs {b:#06x}",
                       ks.name, src[i], src[i].to_bits());
        }
    }
}

// --- companding ----------------------------------------------------------

/// Adversarial momentum/variance groups (GROUP-multiples).
fn adversarial_groups(signed: bool) -> Vec<f32> {
    let mut v: Vec<f32> = Vec::new();
    // all-zero group
    v.extend(std::iter::repeat(0.0f32).take(GROUP));
    // absmax saturates the f16 scale (s > 65504 clamps to fp16::MAX)
    v.extend((0..GROUP).map(|i| 1e30f32 * (i as f32 + 1.0)));
    // denormal-scale group: absmax so tiny its f16 scale rounds to 0,
    // forcing the safe = 1.0 fallback
    v.extend((0..GROUP).map(|i| 1e-42f32 * (i as f32)));
    // f16-subnormal scale
    v.extend((0..GROUP).map(|i| 3e-8f32 * (i as f32 + 1.0)));
    // ±tie-rounding values: group absmax 1.0 (last element), others at
    // exact multiples of 1/254 whose companded code * 127 lands on .5
    let mut tie: Vec<f32> = (0..GROUP - 1)
        .map(|i| (2 * i + 1) as f32 / 254.0)
        .collect();
    tie.push(1.0);
    v.extend(tie.iter().copied());
    // mixed magnitudes across many binades
    v.extend((0..GROUP).map(|i| 2f32.powi(i as i32 - 16)));
    // random heavy-tailed
    let mut rng = Rng::new(0xC0);
    v.extend((0..4 * GROUP).map(|_| {
        let a = rng.normal() as f32;
        let b = (rng.normal() as f32).abs() + 0.3;
        a / b * 0.01
    }));
    if signed {
        // alternate signs to hit the negative companding branch
        for (i, x) in v.iter_mut().enumerate() {
            if i % 2 == 1 {
                *x = -*x;
            }
        }
    } else {
        for x in v.iter_mut() {
            *x = x.abs();
        }
    }
    assert_eq!(v.len() % GROUP, 0);
    v
}

#[test]
fn companded_momentum_kernels_bit_exact() {
    let m = adversarial_groups(true);
    let n = m.len();
    let (mut q_ref, mut s_ref) =
        (vec![0i8; n], vec![0u16; n / GROUP]);
    companding::quant_momentum(&m, &mut q_ref, &mut s_ref);
    let mut out_ref = vec![0f32; n];
    companding::dequant_momentum(&q_ref, &s_ref, &mut out_ref);

    for ks in sets_under_test() {
        let (mut q, mut s) = (vec![0i8; n], vec![0u16; n / GROUP]);
        (ks.quant_momentum)(&m, &mut q, &mut s);
        assert_eq!(q, q_ref, "quant_momentum[{}] codes", ks.name);
        assert_eq!(s, s_ref, "quant_momentum[{}] scales", ks.name);
        let mut out = vec![0f32; n];
        (ks.dequant_momentum)(&q, &s, &mut out);
        assert_f32_bits_eq(&out_ref, &out,
                           &format!("dequant_momentum[{}]", ks.name));
    }

    // linear ablation codec
    let (mut ql_ref, mut sl_ref) =
        (vec![0i8; n], vec![0u16; n / GROUP]);
    companding::quant_momentum_linear(&m, &mut ql_ref, &mut sl_ref);
    let mut outl_ref = vec![0f32; n];
    companding::dequant_momentum_linear(&ql_ref, &sl_ref, &mut outl_ref);
    for ks in sets_under_test() {
        let (mut q, mut s) = (vec![0i8; n], vec![0u16; n / GROUP]);
        (ks.quant_momentum_linear)(&m, &mut q, &mut s);
        assert_eq!(q, ql_ref, "quant_momentum_linear[{}]", ks.name);
        assert_eq!(s, sl_ref, "quant_momentum_linear[{}] scales",
                   ks.name);
        let mut out = vec![0f32; n];
        (ks.dequant_momentum_linear)(&q, &s, &mut out);
        assert_f32_bits_eq(
            &outl_ref, &out,
            &format!("dequant_momentum_linear[{}]", ks.name));
    }
}

#[test]
fn companded_variance_kernels_bit_exact() {
    let mut v = adversarial_groups(false);
    // a group with negative entries: sqrt produces NaN lanes, which the
    // scalar absmax skips and the scalar u8 cast sends to 0 — the SIMD
    // path must emulate both exactly
    v.extend((0..GROUP).map(|i| {
        let x = (i as f32 + 1.0) * 0.01;
        if i % 3 == 0 { -x } else { x }
    }));
    let v = v;
    let n = v.len();
    let (mut q_ref, mut s_ref) =
        (vec![0u8; n], vec![0u16; n / GROUP]);
    companding::quant_variance(&v, &mut q_ref, &mut s_ref);
    let mut out_ref = vec![0f32; n];
    companding::dequant_variance(&q_ref, &s_ref, &mut out_ref);

    for ks in sets_under_test() {
        let (mut q, mut s) = (vec![0u8; n], vec![0u16; n / GROUP]);
        (ks.quant_variance)(&v, &mut q, &mut s);
        assert_eq!(q, q_ref, "quant_variance[{}] codes", ks.name);
        assert_eq!(s, s_ref, "quant_variance[{}] scales", ks.name);
        let mut out = vec![0f32; n];
        (ks.dequant_variance)(&q, &s, &mut out);
        assert_f32_bits_eq(&out_ref, &out,
                           &format!("dequant_variance[{}]", ks.name));
    }

    let (mut ql_ref, mut sl_ref) =
        (vec![0u8; n], vec![0u16; n / GROUP]);
    companding::quant_variance_linear(&v, &mut ql_ref, &mut sl_ref);
    let mut outl_ref = vec![0f32; n];
    companding::dequant_variance_linear(&ql_ref, &sl_ref, &mut outl_ref);
    for ks in sets_under_test() {
        let (mut q, mut s) = (vec![0u8; n], vec![0u16; n / GROUP]);
        (ks.quant_variance_linear)(&v, &mut q, &mut s);
        assert_eq!(q, ql_ref, "quant_variance_linear[{}]", ks.name);
        assert_eq!(s, sl_ref, "quant_variance_linear[{}] scales",
                   ks.name);
        let mut out = vec![0f32; n];
        (ks.dequant_variance_linear)(&q, &s, &mut out);
        assert_f32_bits_eq(
            &outl_ref, &out,
            &format!("dequant_variance_linear[{}]", ks.name));
    }
}

#[test]
fn companding_kernels_random_sweep() {
    // large random buffer: exercises the packed stores across many
    // groups and both signs at many magnitudes
    let mut rng = Rng::new(0xABCD);
    let n = 256 * GROUP;
    let m: Vec<f32> = (0..n)
        .map(|_| {
            let mag = (rng.f32() * 60.0 - 45.0).exp2();
            let sign = if rng.f32() < 0.5 { -1.0 } else { 1.0 };
            sign * mag * (0.5 + rng.f32())
        })
        .collect();
    let (mut q_ref, mut s_ref) = (vec![0i8; n], vec![0u16; n / GROUP]);
    companding::quant_momentum(&m, &mut q_ref, &mut s_ref);
    for ks in sets_under_test() {
        let (mut q, mut s) = (vec![0i8; n], vec![0u16; n / GROUP]);
        (ks.quant_momentum)(&m, &mut q, &mut s);
        assert_eq!(q, q_ref, "random momentum codes [{}]", ks.name);
        assert_eq!(s, s_ref, "random momentum scales [{}]", ks.name);
    }
    let vv: Vec<f32> = m.iter().map(|x| x * x).collect();
    let (mut q_ref, mut s_ref) = (vec![0u8; n], vec![0u16; n / GROUP]);
    companding::quant_variance(&vv, &mut q_ref, &mut s_ref);
    for ks in sets_under_test() {
        let (mut q, mut s) = (vec![0u8; n], vec![0u16; n / GROUP]);
        (ks.quant_variance)(&vv, &mut q, &mut s);
        assert_eq!(q, q_ref, "random variance codes [{}]", ks.name);
        assert_eq!(s, s_ref, "random variance scales [{}]", ks.name);
    }
}

// --- 4-bit nibble-packed codecs (quant4 / mixed84) -----------------------

/// Every possible packed nibble-pair byte — all 256 (low, high) code
/// combinations — decoded under unit, large, small, subnormal, and
/// zero f16 scales, signed (momentum) and unsigned (variance).
#[test]
fn quant4_dequant_all_256_packed_byte_patterns() {
    let q: Vec<u8> = (0..=255u8).collect();
    let n = q.len() * 2; // 512 codes = 16 GROUP-sized groups
    assert_eq!(n % GROUP, 0);
    let scale_bits = [
        0x3C00u16, // 1.0
        0x7BFF,    // f16 max
        0x0400,    // smallest f16 normal
        0x0001,    // smallest f16 subnormal
        0x0000,    // zero scale
        0x3800,    // 0.5
        0x4400,    // 4.0
        0x2E66,    // ~0.1
    ];
    let scales: Vec<u16> = (0..n / GROUP)
        .map(|gi| scale_bits[gi % scale_bits.len()])
        .collect();

    let mut m_ref = vec![0f32; n];
    quant4::dequant_momentum4(&q, &scales, &mut m_ref);
    let mut v_ref = vec![0f32; n];
    quant4::dequant_variance4(&q, &scales, &mut v_ref);

    for ks in sets_under_test() {
        let mut m = vec![0f32; n];
        (ks.dequant_momentum4)(&q, &scales, &mut m);
        assert_f32_bits_eq(&m_ref, &m,
                           &format!("dequant_momentum4[{}]", ks.name));
        let mut v = vec![0f32; n];
        (ks.dequant_variance4)(&q, &scales, &mut v);
        assert_f32_bits_eq(&v_ref, &v,
                           &format!("dequant_variance4[{}]", ks.name));
    }
}

/// The adversarial companding groups (all-zero, f16-scale saturation,
/// denormal scale, ±tie values, cross-binade, heavy-tailed) through
/// the 4-bit momentum codec: codes, scales, and the dequantized
/// round-trip all bit-exact across kernel sets.
#[test]
fn quant4_momentum_codec_bit_exact_on_adversarial_groups() {
    let m = adversarial_groups(true);
    let n = m.len();
    let (mut q_ref, mut s_ref) =
        (vec![0u8; n / 2], vec![0u16; n / GROUP]);
    quant4::quant_momentum4(&m, &mut q_ref, &mut s_ref);
    let mut out_ref = vec![0f32; n];
    quant4::dequant_momentum4(&q_ref, &s_ref, &mut out_ref);

    for ks in sets_under_test() {
        let (mut q, mut s) =
            (vec![0u8; n / 2], vec![0u16; n / GROUP]);
        (ks.quant_momentum4)(&m, &mut q, &mut s);
        assert_eq!(q, q_ref, "quant_momentum4[{}] codes", ks.name);
        assert_eq!(s, s_ref, "quant_momentum4[{}] scales", ks.name);
        let mut out = vec![0f32; n];
        (ks.dequant_momentum4)(&q, &s, &mut out);
        assert_f32_bits_eq(
            &out_ref, &out,
            &format!("quant4 momentum roundtrip[{}]", ks.name));
    }
}

/// Same for the sqrt-domain 4-bit variance codec, with an extra group
/// of negative entries whose sqrt produces NaN lanes: the scalar
/// absmax skips them and the scalar u8 cast sends them to code 0 —
/// the SIMD path must emulate both exactly.
#[test]
fn quant4_variance_codec_bit_exact_on_adversarial_groups() {
    let mut vv = adversarial_groups(false);
    vv.extend((0..GROUP).map(|i| {
        let x = (i as f32 + 1.0) * 0.01;
        if i % 3 == 0 { -x } else { x }
    }));
    let vv = vv;
    let n = vv.len();
    let (mut q_ref, mut s_ref) =
        (vec![0u8; n / 2], vec![0u16; n / GROUP]);
    quant4::quant_variance4(&vv, &mut q_ref, &mut s_ref);
    let mut out_ref = vec![0f32; n];
    quant4::dequant_variance4(&q_ref, &s_ref, &mut out_ref);

    for ks in sets_under_test() {
        let (mut q, mut s) =
            (vec![0u8; n / 2], vec![0u16; n / GROUP]);
        (ks.quant_variance4)(&vv, &mut q, &mut s);
        assert_eq!(q, q_ref, "quant_variance4[{}] codes", ks.name);
        assert_eq!(s, s_ref, "quant_variance4[{}] scales", ks.name);
        let mut out = vec![0f32; n];
        (ks.dequant_variance4)(&q, &s, &mut out);
        assert_f32_bits_eq(
            &out_ref, &out,
            &format!("quant4 variance roundtrip[{}]", ks.name));
    }
}

// --- weight splitting ----------------------------------------------------

fn split_inputs() -> Vec<f32> {
    let mut rng = Rng::new(0x5117);
    let mut v: Vec<f32> = (0..8192)
        .map(|_| {
            let mag = (rng.f32() * 40.0 - 30.0).exp2();
            let sign = if rng.f32() < 0.5 { -1.0 } else { 1.0 };
            sign * mag * (0.5 + rng.f32())
        })
        .collect();
    v.extend_from_slice(&[
        0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN,
        f32::from_bits(1), f32::from_bits(0x007F_FFFF),
        f32::MIN_POSITIVE, f32::MAX, f32::MIN, 1.0, -1.0,
        1.0 + 2f32.powi(-8), // bf16 RNE tie
    ]);
    // odd length on purpose: exercises the vector tails
    v.push(0.12345f32);
    v
}

#[test]
fn weight_split_kernels_bit_exact() {
    let theta = split_inputs();
    let n = theta.len();
    let (mut tp_ref, mut rho_ref) = (vec![0u16; n], vec![0i8; n]);
    weight_split::compress_slice(&theta, &mut tp_ref, &mut rho_ref);
    let mut out_ref = vec![0f32; n];
    weight_split::decompress_slice(&tp_ref, &rho_ref, &mut out_ref);

    for ks in sets_under_test() {
        let (mut tp, mut rho) = (vec![0u16; n], vec![0i8; n]);
        (ks.split_compress)(&theta, &mut tp, &mut rho);
        assert_eq!(tp, tp_ref, "split_compress[{}] theta_p", ks.name);
        assert_eq!(rho, rho_ref, "split_compress[{}] rho", ks.name);
        let mut out = vec![0f32; n];
        (ks.split_decompress)(&tp, &rho, &mut out);
        assert_f32_bits_eq(&out_ref, &out,
                           &format!("split_decompress[{}]", ks.name));
    }
}

// --- fused single-pass step kernels --------------------------------------

fn assert_states_eq(a: &State, b: &State, what: &str) {
    assert_eq!(a.theta_p, b.theta_p, "{what}: theta_p");
    assert_eq!(a.rho, b.rho, "{what}: rho");
    assert_eq!(a.mq, b.mq, "{what}: mq");
    assert_eq!(a.ms, b.ms, "{what}: ms");
    assert_eq!(a.vq, b.vq, "{what}: vq");
    assert_eq!(a.vs, b.vs, "{what}: vs");
    assert_eq!(a.mq4, b.mq4, "{what}: mq4");
    assert_eq!(a.vq4, b.vq4, "{what}: vq4");
    // the fp32-resident buffers compare by raw bits (NaN payloads and
    // signed zeros included), not by float equality
    for (name, x, y) in [("theta", &a.theta, &b.theta),
                         ("m", &a.m, &b.m), ("v", &a.v, &b.v)] {
        match (x, y) {
            (Some(x), Some(y)) => {
                assert_eq!(x.len(), y.len(), "{what}: {name} len");
                for (i, (p, q)) in x.iter().zip(y).enumerate() {
                    assert_eq!(p.to_bits(), q.to_bits(),
                               "{what}: {name}[{i}] {p:?} \
                                ({:#010x}) vs {q:?} ({:#010x})",
                               p.to_bits(), q.to_bits());
                }
            }
            (None, None) => {}
            _ => panic!("{what}: {name} presence differs"),
        }
    }
}

/// Adversarial master weights for the fused sweeps: the signed
/// companding groups (all-zero, f16-scale saturation, denormal scale,
/// tie values, cross-binade, heavy-tailed) reused as weights, plus an
/// all-inf group and a NaN-bearing group.
fn fused_adversarial_theta() -> Vec<f32> {
    let mut v = adversarial_groups(true);
    v.extend((0..GROUP).map(|i| {
        if i % 2 == 0 { f32::INFINITY } else { f32::NEG_INFINITY }
    }));
    v.extend((0..GROUP).map(|i| {
        if i % 4 == 0 {
            f32::from_bits(0x7FC0_0000 | (i as u32 * 0x1357 + 1))
        } else {
            0.25 * (i as f32 - 15.0)
        }
    }));
    assert_eq!(v.len() % GROUP, 0);
    v
}

/// Adversarial gradients in the variant's dtype semantics
/// (bf16-rounded for the split-weight variants, raw f32 for
/// `reference`/`quant`): zeros, saturating magnitudes, denormals,
/// ties, and — when `with_nan` — payload-carrying quiet NaNs plus one
/// signaling NaN (quieted by the bf16 rounding on split tracks, and
/// deterministically quieted by the first arithmetic op on the raw
/// tracks).
fn fused_adversarial_grads(n: usize, variant: Variant,
                           with_nan: bool) -> Vec<f32> {
    let mut rng = Rng::new(0xFAD5);
    let mut g: Vec<f32> = (0..n)
        .map(|i| match (i / GROUP) % 5 {
            0 => 0.0,
            1 => 1e30 * ((i % GROUP) as f32 + 1.0),
            2 => f32::from_bits(1 + (i as u32 % 0xFFFF)),
            3 => (2 * (i % GROUP) + 1) as f32 / 254.0,
            _ => {
                let a = rng.normal() as f32;
                let b = (rng.normal() as f32).abs() + 0.3;
                a / b * 0.01
            }
        })
        .collect();
    if with_nan {
        for (i, x) in g.iter_mut().enumerate().skip(7).step_by(37) {
            *x = f32::from_bits(0x7FC0_0000 | (i as u32 & 0x3F_FFFF));
        }
        g[3] = f32::from_bits(0x7F80_0001); // sNaN
    }
    if variant.splits_weights() {
        g.iter()
            .map(|&x| flashtrain::formats::bf16::round_f32_to_bf16(x))
            .collect()
    } else {
        g
    }
}

/// Fused-kernel adversarial sweep, mirroring the per-codec groups
/// above through the *whole* single-pass step: the full 21-pair
/// (optimizer, variant) universe, every kernel set, against the tiled
/// path and the legacy scalar mirror — including a negative-beta2
/// hyper vector that drives the variance negative (sqrt -> NaN lanes
/// inside requant, or a persistent NaN fp32 variance on the
/// fp32-resident layouts), a zero-eps vector (0/0), and a saturating
/// learning rate.
#[test]
fn fused_step_kernels_bit_exact_on_adversarial_groups() {
    let theta0 = fused_adversarial_theta();
    let n = theta0.len();
    let cfg = TrainConfig::default(); // wd = 0.1 (nonzero: see fuzzer)
    let base = Hyper::for_step(&cfg, 1e-3, 3);
    let mut neg_var = base;
    neg_var.beta2 = -0.5; // negative variance -> NaN through requant
    let mut zero_eps = base;
    zero_eps.eps = 0.0;
    let mut huge_lr = base;
    huge_lr.lr = 1e30; // saturates the split-weight range
    let hypers = [("base", base), ("neg_var", neg_var),
                  ("zero_eps", zero_eps), ("huge_lr", huge_lr)];

    for opt in [OptKind::Sgd, OptKind::AdamW, OptKind::Lion] {
        for variant in [Variant::Reference, Variant::Flash,
                        Variant::WeightSplit, Variant::OptQuant,
                        Variant::NoCompand, Variant::Quant4,
                        Variant::Mixed84] {
            for ks in sets_under_test() {
                // total coverage: the typed binding fails to compile
                // if `fused_step` ever regresses to an Option return
                let _kernel: flashtrain::kernels::FusedStepFn =
                    ks.fused_step(opt, variant);
                for (hname, h) in &hypers {
                    let g = fused_adversarial_grads(n, variant, true);
                    let mut legacy =
                        State::init(&theta0, n, opt, variant);
                    let mut tiled = legacy.clone();
                    let mut fused = legacy.clone();
                    for step in 0..3 {
                        scalar_ref::step_state(&mut legacy, &g, opt,
                                               variant, h);
                        let mut part =
                            Part::of_range(&mut tiled, 0, n, &g);
                        step_part(&mut part, opt, variant, h, ks,
                                  false);
                        let mut part =
                            Part::of_range(&mut fused, 0, n, &g);
                        step_part(&mut part, opt, variant, h, ks,
                                  true);
                        let what = format!(
                            "{opt}/{variant}/{}/{hname} step {step}",
                            ks.name);
                        assert_states_eq(&legacy, &tiled,
                                         &format!("{what} tiled"));
                        assert_states_eq(&legacy, &fused,
                                         &format!("{what} fused"));
                    }
                }
            }
        }
    }
}

/// Zero-wd hypers are exercised with NaN-free gradients (the one
/// IEEE-underdetermined payload corner — see fused_fuzz — is excluded;
/// everything else about wd = 0 must still be bit-exact), one pair
/// per layout family including the fp32-resident ones.
#[test]
fn fused_step_kernels_bit_exact_with_zero_weight_decay() {
    let theta0 = fused_adversarial_theta();
    let n = theta0.len();
    let cfg = TrainConfig {
        weight_decay: 0.0,
        ..Default::default()
    };
    let h = Hyper::for_step(&cfg, 1e-3, 1);
    for (opt, variant) in [(OptKind::AdamW, Variant::Flash),
                           (OptKind::Sgd, Variant::Flash),
                           (OptKind::Lion, Variant::NoCompand),
                           (OptKind::AdamW, Variant::Reference),
                           (OptKind::Sgd, Variant::WeightSplit),
                           (OptKind::Lion, Variant::OptQuant),
                           (OptKind::AdamW, Variant::Quant4),
                           (OptKind::Sgd, Variant::Mixed84)] {
        let g = fused_adversarial_grads(n, variant, false);
        for ks in sets_under_test() {
            let mut legacy = State::init(&theta0, n, opt, variant);
            scalar_ref::step_state(&mut legacy, &g, opt, variant, &h);
            let mut fused = State::init(&theta0, n, opt, variant);
            let mut part = Part::of_range(&mut fused, 0, n, &g);
            step_part(&mut part, opt, variant, &h, ks, true);
            assert_states_eq(
                &legacy, &fused,
                &format!("{opt}/{variant}/{} wd=0", ks.name));
        }
    }
}

#[test]
fn kernels_handle_short_and_empty_slices() {
    // below every vector width: everything goes through the tails
    for ks in sets_under_test() {
        for n in [0usize, 1, 3, 7, 15, 31] {
            let theta: Vec<f32> =
                (0..n).map(|i| i as f32 * 0.25 - 1.0).collect();
            let (mut tp, mut rho) = (vec![0u16; n], vec![0i8; n]);
            (ks.split_compress)(&theta, &mut tp, &mut rho);
            let mut out = vec![0f32; n];
            (ks.split_decompress)(&tp, &rho, &mut out);
            let (mut tp_ref, mut rho_ref) =
                (vec![0u16; n], vec![0i8; n]);
            weight_split::compress_slice(&theta, &mut tp_ref,
                                         &mut rho_ref);
            assert_eq!(tp, tp_ref, "n={n} [{}]", ks.name);
            assert_eq!(rho, rho_ref, "n={n} [{}]", ks.name);

            let mut bits = vec![0u16; n];
            (ks.f32_to_f16)(&theta, &mut bits);
            let mut bits_ref = vec![0u16; n];
            for (d, &s) in bits_ref.iter_mut().zip(&theta) {
                *d = fp16::f32_to_f16_bits(s);
            }
            assert_eq!(bits, bits_ref, "f16 n={n} [{}]", ks.name);
        }
    }
}
