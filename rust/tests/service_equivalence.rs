//! The multi-tenant service's headline contract (docs/SERVICE.md): N
//! tenants interleaved on ONE shared engine — cross-tenant batched
//! dispatch, DRR scheduling, park/unpark round trips included — finish
//! with byte-identical state to N standalone runs fed the same
//! gradient streams.  Plus the service's operational properties:
//! the DRR fairness bound, disk spooling, per-tenant byte accounting
//! against `memory::per_param`, and failure isolation.
//!
//! Everything here is artifact-free: tenants run deterministic
//! synthetic workloads (seeded init + gradient streams), so the
//! comparisons need no HLO manifests or PJRT runtime.

use std::path::PathBuf;
use std::rc::Rc;

use flashtrain::backend::StepBackend;
use flashtrain::checkpoint;
use flashtrain::config::{BackendKind, KernelKind, OptKind,
                         ServiceConfig, TrainConfig, Variant};
use flashtrain::coordinator::{make_engine, Schedule};
use flashtrain::formats::GROUP;
use flashtrain::memory::per_param;
use flashtrain::memory::tracker::Category;
use flashtrain::optim::{FlashOptimizer, GroupHyper, GroupSpec,
                        HyperDefaults, StateDict};
use flashtrain::service::{GradFn, Service, TenantPhase, TenantSpec};
use flashtrain::util::rng::Rng;

/// (optimizer, variant) pairs spanning the format families: plain
/// f32, 4-bit, mixed 8/4, reference, and weight splitting.
const PAIRS: [(OptKind, Variant); 5] = [
    (OptKind::AdamW, Variant::Flash),
    (OptKind::AdamW, Variant::Quant4),
    (OptKind::Lion, Variant::Mixed84),
    (OptKind::Sgd, Variant::Reference),
    (OptKind::AdamW, Variant::WeightSplit),
];

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("flashtrain_svc_{}_{name}",
                                      std::process::id()))
}

fn theta0(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x5eed_f1a5);
    (0..n).map(|_| rng.normal() as f32 * 0.02).collect()
}

/// Deterministic in (seed, t): both the service tenant and its
/// standalone twin regenerate the identical stream.
fn fill_grad(seed: u64, t: u64, buf: &mut [f32]) {
    let mut rng =
        Rng::new(seed ^ t.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    for x in buf.iter_mut() {
        *x = rng.normal() as f32 * 0.1;
    }
}

fn grad_fn_for(seed: u64) -> GradFn {
    Box::new(move |t, buf| fill_grad(seed, t, buf))
}

fn tcfg(opt: OptKind, variant: Variant, steps: usize, lr: f64,
        warmup: usize, backend: BackendKind, threads: usize,
        fused: bool) -> TrainConfig {
    TrainConfig {
        optimizer: opt,
        variant,
        steps,
        lr,
        warmup,
        final_lr_frac: 0.1,
        bucket: 2 * GROUP,
        backend,
        threads,
        kernels: KernelKind::Auto,
        fused_step: fused,
        ..TrainConfig::default()
    }
}

/// Two groups with different hyper overrides — per-tenant *and*
/// per-group isolation ride through the same batched dispatches.
fn two_groups(n: usize) -> Vec<GroupSpec> {
    let half = n / 2;
    vec![
        GroupSpec {
            name: "body".into(),
            ranges: vec![(0, half)],
            hyper: GroupHyper::default(),
        },
        GroupSpec {
            name: "head".into(),
            ranges: vec![(half, n)],
            hyper: GroupHyper {
                lr_scale: Some(0.5),
                weight_decay: Some(0.0),
                ..GroupHyper::default()
            },
        },
    ]
}

/// The tenant's standalone twin: same config, same specs, same init,
/// same gradient stream — on its own freshly constructed engine.
fn standalone_final_state(cfg: &TrainConfig, specs: Vec<GroupSpec>,
                          init: &[f32], seed: u64) -> StateDict {
    let mut opt = FlashOptimizer::native_with_opts(
        cfg.optimizer, cfg.variant, cfg.bucket, init, specs,
        HyperDefaults::of(cfg), cfg.backend, cfg.threads, cfg.kernels,
        cfg.fused_step)
        .unwrap();
    let sched = Schedule::warmup_cosine(
        cfg.lr, cfg.lr * cfg.final_lr_frac, cfg.warmup, cfg.steps);
    let mut g = vec![0.0f32; init.len()];
    for t in 1..=cfg.steps {
        fill_grad(seed, t as u64, &mut g);
        opt.step(&g, sched.lr(t), t, |_, _| {}).unwrap();
    }
    opt.state_dict(cfg.steps as u64)
}

/// Byte-serialize a state dict through the v2 checkpoint writer (the
/// format is byte-deterministic, so equality of these buffers is
/// equality of every weight, moment, scale, and counter bit).
fn dict_bytes(sd: &StateDict, tag: &str) -> Vec<u8> {
    let path = tmp(&format!("{tag}.flt"));
    checkpoint::save_state_dict(&path, sd).unwrap();
    let b = std::fs::read(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    b
}

/// Build a service, admit 3 tenants with distinct configs/seeds on
/// the given engine settings, run it to completion, and byte-compare
/// every tenant's final state to its standalone twin.
fn run_and_compare(backend: BackendKind, threads: usize, fused: bool,
                   svc_cfg: &ServiceConfig, tag: &str) {
    let sizes = [8 * GROUP, 12 * GROUP, 16 * GROUP];
    let steps = [7usize, 12, 5];
    for &(opt, variant) in &PAIRS {
        let engine_cfg = tcfg(opt, variant, 1, 1e-3, 1, backend,
                              threads, fused);
        let engine: Rc<dyn StepBackend> =
            make_engine(&engine_cfg).unwrap();
        let mut svc = Service::new(engine, svc_cfg).unwrap();

        let mut twins: Vec<(TrainConfig, Vec<GroupSpec>, Vec<f32>, u64)> =
            Vec::new();
        for i in 0..3u64 {
            let cfg = tcfg(opt, variant, steps[i as usize],
                           6e-4 * (i + 1) as f64, i as usize + 1,
                           backend, threads, fused);
            let n = sizes[i as usize];
            let init = theta0(n, 100 + i);
            let specs = two_groups(n);
            svc.admit(
                TenantSpec {
                    name: format!("tenant{i}"),
                    cfg: cfg.clone(),
                    specs: specs.clone(),
                    theta0: init.clone(),
                },
                grad_fn_for(200 + i))
                .unwrap();
            twins.push((cfg, specs, init, 200 + i));
        }

        svc.run().unwrap();
        assert!(svc.all_done());

        for (id, (cfg, specs, init, seed)) in
            twins.into_iter().enumerate()
        {
            let t = svc.tenant(id);
            assert_eq!(t.phase(), TenantPhase::Finished,
                       "{tag} {opt:?}/{variant:?} tenant{id}: {:?}",
                       t.error());
            assert_eq!(t.completed_steps(), cfg.steps as u64);
            let shared = t.latest_state().unwrap();
            let alone = standalone_final_state(&cfg, specs, &init,
                                               seed);
            assert_eq!(
                dict_bytes(&shared,
                           &format!("{tag}_shared_{id}")),
                dict_bytes(&alone, &format!("{tag}_alone_{id}")),
                "{tag} {opt:?}/{variant:?} tenant{id}: shared-engine \
                 state diverged from the standalone run"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// the headline contract, across engine shapes

#[test]
fn shared_engine_matches_standalone_runs() {
    // max_resident 2 of 3 forces park/unpark round trips mid-run;
    // quantum 2 forces fine-grained interleaving
    let svc_cfg = ServiceConfig {
        tenants: 3,
        quantum: 2,
        max_resident: 2,
        spool: None,
    };
    for threads in [1usize, 4] {
        for fused in [true, false] {
            run_and_compare(BackendKind::Parallel, threads, fused,
                            &svc_cfg,
                            &format!("par_t{threads}_f{fused}"));
        }
    }
}

#[test]
fn scalar_engine_path_matches_standalone() {
    // the sequential engine has no pool to batch into — the service
    // takes the per-tenant step_now path, which must land on the
    // identical bits
    let svc_cfg = ServiceConfig {
        tenants: 3,
        quantum: 2,
        max_resident: 2,
        spool: None,
    };
    run_and_compare(BackendKind::Scalar, 0, true, &svc_cfg, "scalar");
}

#[test]
fn batching_and_parking_actually_happen() {
    // guard against the equivalence tests passing vacuously: the
    // parallel run must batch multiple tenants' jobs per dispatch and
    // rotate someone through a park/unpark round trip
    let svc_cfg = ServiceConfig {
        tenants: 3,
        quantum: 2,
        max_resident: 2,
        spool: None,
    };
    let engine_cfg = tcfg(OptKind::AdamW, Variant::Flash, 1, 1e-3, 1,
                          BackendKind::Parallel, 2, true);
    let engine: Rc<dyn StepBackend> = make_engine(&engine_cfg).unwrap();
    let mut svc = Service::new(engine, &svc_cfg).unwrap();
    for i in 0..3u64 {
        let n = 8 * GROUP;
        let cfg = tcfg(OptKind::AdamW, Variant::Flash, 8, 6e-4, 2,
                       BackendKind::Parallel, 2, true);
        svc.admit(
            TenantSpec {
                name: format!("tenant{i}"),
                cfg,
                specs: two_groups(n),
                theta0: theta0(n, i),
            },
            grad_fn_for(i))
            .unwrap();
    }
    svc.run().unwrap();
    assert!(svc.dispatches() > 0);
    // 2 resident tenants × 2 groups = 4 jobs per full tick
    assert!(svc.batched_jobs() > svc.dispatches(),
            "dispatches {} carried only {} jobs — cross-tenant \
             batching never happened",
            svc.dispatches(), svc.batched_jobs());
    assert!(
        svc.tenants().iter().any(|t| t.park_round_trips() > 0),
        "max_resident < tenants but nobody took a park round trip");
}

// ---------------------------------------------------------------------------
// DRR fairness

#[test]
fn fairness_spread_bounded_by_quantum() {
    let quantum = 4u64;
    let svc_cfg = ServiceConfig {
        tenants: 4,
        quantum,
        max_resident: 2,
        spool: None,
    };
    let engine_cfg = tcfg(OptKind::AdamW, Variant::Flash, 1, 1e-3, 1,
                          BackendKind::Parallel, 2, true);
    let engine: Rc<dyn StepBackend> = make_engine(&engine_cfg).unwrap();
    let mut svc = Service::new(engine, &svc_cfg).unwrap();
    let n = 4 * GROUP;
    for i in 0..4u64 {
        let cfg = tcfg(OptKind::AdamW, Variant::Flash, 32, 6e-4, 4,
                       BackendKind::Parallel, 2, true);
        svc.admit(
            TenantSpec {
                name: format!("tenant{i}"),
                cfg,
                specs: GroupSpec::single(n),
                theta0: theta0(n, i),
            },
            grad_fn_for(i))
            .unwrap();
    }
    // equal demand → the DRR bound holds at every round boundary:
    // served-step counts never diverge by more than one quantum
    while svc.run_round().unwrap() {
        let served: Vec<u64> = svc
            .tenants()
            .iter()
            .map(|t| t.completed_steps())
            .collect();
        let hi = *served.iter().max().unwrap();
        let lo = *served.iter().min().unwrap();
        assert!(hi - lo <= quantum,
                "unfair round {}: served {served:?}, spread {} > \
                 quantum {quantum}",
                svc.rounds(), hi - lo);
    }
    assert!(svc
        .tenants()
        .iter()
        .all(|t| t.phase() == TenantPhase::Finished));
}

// ---------------------------------------------------------------------------
// disk spool

#[test]
fn disk_spool_round_trips_are_bit_exact() {
    let spool = tmp("spool_dir");
    let _ = std::fs::remove_dir_all(&spool);
    let svc_cfg = ServiceConfig {
        tenants: 3,
        quantum: 2,
        max_resident: 1, // everyone commutes through the spool
        spool: Some(spool.to_string_lossy().into_owned()),
    };
    let (opt, variant) = (OptKind::AdamW, Variant::Quant4);
    let engine_cfg = tcfg(opt, variant, 1, 1e-3, 1,
                          BackendKind::Parallel, 2, true);
    let engine: Rc<dyn StepBackend> = make_engine(&engine_cfg).unwrap();
    let mut svc = Service::new(engine, &svc_cfg).unwrap();
    let mut twins = Vec::new();
    for i in 0..3u64 {
        let n = 8 * GROUP;
        let cfg = tcfg(opt, variant, 6, 6e-4, 2,
                       BackendKind::Parallel, 2, true);
        let init = theta0(n, 300 + i);
        svc.admit(
            TenantSpec {
                name: format!("tenant{i}"),
                cfg: cfg.clone(),
                specs: GroupSpec::single(n),
                theta0: init.clone(),
            },
            grad_fn_for(400 + i))
            .unwrap();
        twins.push((cfg, init, 400 + i));
    }
    svc.run().unwrap();
    for (id, (cfg, init, seed)) in twins.into_iter().enumerate() {
        let t = svc.tenant(id);
        assert_eq!(t.phase(), TenantPhase::Finished, "{:?}", t.error());
        assert!(t.park_round_trips() > 0,
                "tenant{id} never round-tripped the spool");
        // the parked file is on disk and is the final state
        assert!(spool.join(format!("tenant{id}.flt")).is_file());
        let shared = t.latest_state().unwrap();
        let alone = standalone_final_state(
            &cfg, GroupSpec::single(init.len()), &init, seed);
        assert_eq!(dict_bytes(&shared, &format!("spool_shared_{id}")),
                   dict_bytes(&alone, &format!("spool_alone_{id}")),
                   "tenant{id} diverged across spool round trips");
    }
    let _ = std::fs::remove_dir_all(&spool);
}

// ---------------------------------------------------------------------------
// per-tenant byte accounting

#[test]
fn per_tenant_bytes_match_the_model() {
    for &(opt, variant) in
        &[(OptKind::AdamW, Variant::Flash),
          (OptKind::AdamW, Variant::Quant4)]
    {
        let svc_cfg = ServiceConfig {
            tenants: 2,
            quantum: 4,
            max_resident: 0, // everyone stays resident
            spool: None,
        };
        let engine_cfg = tcfg(opt, variant, 1, 1e-3, 1,
                              BackendKind::Parallel, 2, true);
        let engine: Rc<dyn StepBackend> =
            make_engine(&engine_cfg).unwrap();
        let mut svc = Service::new(engine, &svc_cfg).unwrap();
        let n = 64 * GROUP; // aligned: measured == analytic exactly
        for i in 0..2u64 {
            let cfg = tcfg(opt, variant, 8, 6e-4, 2,
                           BackendKind::Parallel, 2, true);
            svc.admit(
                TenantSpec {
                    name: format!("tenant{i}"),
                    cfg,
                    specs: GroupSpec::single(n),
                    theta0: theta0(n, i),
                },
                grad_fn_for(i))
                .unwrap();
        }
        // after one round (quantum < steps) both tenants are resident
        // with live tracked state
        assert!(svc.run_round().unwrap());
        let geb: u64 = if variant.splits_weights() { 2 } else { 4 };
        let model = per_param(opt, variant, false).total();
        let mut tracked_total = 0u64;
        for t in svc.tenants() {
            assert_eq!(t.phase(), TenantPhase::Resident);
            let bpp =
                (t.state_bytes() + n as u64 * geb) as f64 / n as f64;
            assert!((bpp - model).abs() < 0.01,
                    "{opt:?}/{variant:?} {}: measured {bpp:.4} \
                     B/param, model {model:.4}",
                    t.name);
            tracked_total += t.state_bytes() + n as u64 * geb;
        }
        // the shared tracker's live categories account exactly the
        // residents' state + gradients
        let tr = svc.tracker();
        let live = tr.category_live(Category::Params)
            + tr.category_live(Category::OptimState)
            + tr.category_live(Category::Gradients);
        assert_eq!(live, tracked_total);
        // per-tenant rows surface under the tenant's name
        let rows = svc.tenant_bytes();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|(name, bytes)| {
            name.starts_with("tenant") && *bytes > 0
        }));
        // finish the run: parking releases every tracked byte
        svc.run().unwrap();
        let tr = svc.tracker();
        assert_eq!(tr.current_bytes(), 0,
                   "parked/finished tenants left live tracker bytes");
    }
}

// ---------------------------------------------------------------------------
// failure isolation

#[test]
fn failed_tenant_does_not_poison_the_fleet() {
    let svc_cfg = ServiceConfig {
        tenants: 3,
        quantum: 2,
        max_resident: 2,
        spool: None,
    };
    let engine_cfg = tcfg(OptKind::AdamW, Variant::Flash, 1, 1e-3, 1,
                          BackendKind::Parallel, 2, true);
    let engine: Rc<dyn StepBackend> = make_engine(&engine_cfg).unwrap();
    let mut svc = Service::new(engine, &svc_cfg).unwrap();
    let n = 8 * GROUP;
    let mut twins = Vec::new();
    for i in 0..3u64 {
        let cfg = tcfg(OptKind::AdamW, Variant::Flash, 6, 6e-4, 2,
                       BackendKind::Parallel, 2, true);
        // tenant1's groups overlap: the span matches (so admission
        // passes) but materialization must fail on the tiling check
        let specs = if i == 1 {
            let half = n / 2;
            vec![
                GroupSpec {
                    name: "a".into(),
                    ranges: vec![(0, half)],
                    hyper: GroupHyper::default(),
                },
                GroupSpec {
                    name: "b".into(),
                    ranges: vec![(half / 2, half / 2 + half)],
                    hyper: GroupHyper::default(),
                },
            ]
        } else {
            two_groups(n)
        };
        let init = theta0(n, 500 + i);
        svc.admit(
            TenantSpec {
                name: format!("tenant{i}"),
                cfg: cfg.clone(),
                specs: specs.clone(),
                theta0: init.clone(),
            },
            grad_fn_for(600 + i))
            .unwrap();
        twins.push((cfg, specs, init, 600 + i));
    }
    svc.run().unwrap();
    assert!(svc.all_done());

    let bad = svc.tenant(1);
    assert_eq!(bad.phase(), TenantPhase::Failed);
    assert!(bad.error().unwrap().contains("gap or overlap"),
            "{:?}", bad.error());
    assert_eq!(bad.completed_steps(), 0);

    // the healthy tenants finish bit-exact to their standalone twins
    for id in [0usize, 2] {
        let (cfg, specs, init, seed) = twins[id].clone();
        let t = svc.tenant(id);
        assert_eq!(t.phase(), TenantPhase::Finished, "{:?}", t.error());
        let shared = t.latest_state().unwrap();
        let alone = standalone_final_state(&cfg, specs, &init, seed);
        assert_eq!(dict_bytes(&shared, &format!("fail_shared_{id}")),
                   dict_bytes(&alone, &format!("fail_alone_{id}")),
                   "tenant{id} perturbed by tenant1's failure");
    }
}
