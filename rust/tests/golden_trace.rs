//! Golden-trace regression harness: short end-to-end optimizer runs
//! with pinned CRC32 checksums of the final parameters + optimizer
//! state, so cross-PR numeric drift in *any* layer (codecs, kernels,
//! update rules, hyper resolution, group plumbing, checkpoint state
//! assembly) fails loudly instead of silently shifting results.
//!
//! One trace per optimizer family (adamw / sgd / lion, `flash`
//! variant, two param groups with overrides, scalar backend + scalar
//! kernels, fixed seed).  Every input is derived from `util::rng::Rng`
//! bits through exact power-of-two arithmetic only — no libm calls —
//! so the checksums are identical on any IEEE-754 platform, not just
//! the machine that generated them.
//!
//! Workflow:
//! * `cargo test --test golden_trace` — compares against
//!   `tests/golden/golden_trace.txt`; a mismatch is a real numeric
//!   change and must be explained (then regenerated deliberately).
//! * missing golden file — the run seeds it, prints the checksums, and
//!   passes with a note asking to commit the file.
//! * `UPDATE_GOLDEN=1 cargo test --test golden_trace` — regenerates
//!   and prints the checksums unconditionally.
//!
//! CI carries the checksums across runs through a side cache that is
//! only copied into place when no golden file is committed (a
//! committed file always wins — see ci.yml and tests/golden/README.md),
//! so drift between consecutive CI runs on main fails even before the
//! file is committed.

use std::fmt::Write as _;
use std::path::PathBuf;

use flashtrain::checkpoint::crc32::crc32;
use flashtrain::config::{BackendKind, KernelKind, OptKind, TrainConfig,
                         Variant};
use flashtrain::formats::weight_split::pow2;
use flashtrain::optim::{FlashOptimizer, GroupHyper, GroupSpec,
                        HyperDefaults};
use flashtrain::util::rng::Rng;

const STEPS: usize = 20;
const PARAMS: usize = 700; // deliberately unaligned (padding paths)
const BUCKET: usize = 128;
/// 2^-10: exactly representable so the schedule math is libm-free.
const LR: f64 = 0.0009765625;

const FAMILIES: [(OptKind, &str); 3] = [
    (OptKind::AdamW, "adamw_flash"),
    (OptKind::Sgd, "sgd_flash"),
    (OptKind::Lion, "lion_flash"),
];

/// Deterministic value from raw RNG bits: a 24-bit uniform fraction in
/// [-1, 1) times an exact power of two.  Integer→f32 conversion of a
/// 24-bit value and multiplication by 2^k are both exact, so identical
/// bits fall out on every conforming platform.
fn det_val(rng: &mut Rng) -> f32 {
    let u = rng.u64();
    let frac = (u >> 40) as f32 * (1.0 / (1u64 << 23) as f32) - 1.0;
    let e = ((u >> 32) & 0xF) as i32;
    frac * pow2(e - 12)
}

fn det_vec(rng: &mut Rng, n: usize, scale_exp: i32) -> Vec<f32> {
    (0..n).map(|_| det_val(rng) * pow2(scale_exp)).collect()
}

/// Two groups with different overrides, tiling the parameter vector:
/// exercises per-group hyper resolution and the gather/scatter paths.
fn specs() -> Vec<GroupSpec> {
    let cut = 300;
    vec![
        GroupSpec {
            name: "head".into(),
            ranges: vec![(0, cut)],
            hyper: GroupHyper {
                weight_decay: Some(0.0),
                ..Default::default()
            },
        },
        GroupSpec {
            name: "body".into(),
            ranges: vec![(cut, PARAMS)],
            hyper: GroupHyper {
                lr_scale: Some(0.5),
                ..Default::default()
            },
        },
    ]
}

fn push_bytes<T, F: Fn(&T, &mut Vec<u8>)>(out: &mut Vec<u8>, tag: u8,
                                          v: &Option<Vec<T>>, f: F) {
    out.push(tag);
    match v {
        Some(v) => {
            out.extend_from_slice(&(v.len() as u64).to_le_bytes());
            for x in v {
                f(x, out);
            }
        }
        None => out.extend_from_slice(&u64::MAX.to_le_bytes()),
    }
}

/// Run one family trace and checksum the final state dict + compute
/// weights.  `streaming` routes every step through the
/// gradient-release streaming path; `sharded` turns on shard-owner
/// execution (`shard_state`).  Both must land on the exact same
/// pinned checksum as the plain batch step.
#[allow(clippy::too_many_arguments)]
fn run_trace(opt: OptKind, variant: Variant, backend: BackendKind,
             threads: usize, kernels: KernelKind, fused: bool,
             streaming: bool, sharded: bool) -> u32 {
    let cfg = TrainConfig {
        optimizer: opt,
        variant,
        ..Default::default()
    };
    let mut rng = Rng::new(0x601D ^ opt.name().len() as u64);
    let theta0 = det_vec(&mut rng, PARAMS, 0);
    let mut fo = FlashOptimizer::native_with_opts(
        opt, variant, BUCKET, &theta0, specs(),
        HyperDefaults::of(&cfg), backend, threads, kernels, fused)
        .expect("building the golden-trace optimizer");
    fo.set_shard_state(sharded);
    for t in 1..=STEPS {
        let g = det_vec(&mut rng, PARAMS, -5);
        if streaming {
            fo.step_streaming(&g, LR, t, |_, _| {})
                .expect("golden-trace streaming step");
        } else {
            fo.step(&g, LR, t, |_, _| {}).expect("golden-trace step");
        }
    }

    let sd = fo.state_dict(STEPS as u64);
    let mut bytes: Vec<u8> = Vec::new();
    for gs in &sd.groups {
        bytes.extend_from_slice(gs.name.as_bytes());
        bytes.extend_from_slice(&gs.param_count.to_le_bytes());
        let st = &gs.state;
        bytes.extend_from_slice(&(st.n as u64).to_le_bytes());
        push_bytes(&mut bytes, 1, &st.theta,
                   |x, o| o.extend_from_slice(&x.to_bits().to_le_bytes()));
        push_bytes(&mut bytes, 2, &st.theta_p,
                   |x, o| o.extend_from_slice(&x.to_le_bytes()));
        push_bytes(&mut bytes, 3, &st.rho,
                   |x, o| o.push(*x as u8));
        push_bytes(&mut bytes, 4, &st.m,
                   |x, o| o.extend_from_slice(&x.to_bits().to_le_bytes()));
        push_bytes(&mut bytes, 5, &st.v,
                   |x, o| o.extend_from_slice(&x.to_bits().to_le_bytes()));
        push_bytes(&mut bytes, 6, &st.mq,
                   |x, o| o.push(*x as u8));
        push_bytes(&mut bytes, 7, &st.ms,
                   |x, o| o.extend_from_slice(&x.to_le_bytes()));
        push_bytes(&mut bytes, 8, &st.vq,
                   |x, o| o.push(*x));
        push_bytes(&mut bytes, 9, &st.vs,
                   |x, o| o.extend_from_slice(&x.to_le_bytes()));
        push_bytes(&mut bytes, 10, &st.mq4,
                   |x, o| o.push(*x));
        push_bytes(&mut bytes, 11, &st.vq4,
                   |x, o| o.push(*x));
    }
    for w in fo.compute_weights_bf16(PARAMS) {
        bytes.extend_from_slice(&w.to_le_bytes());
    }
    crc32(&bytes)
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/golden_trace.txt")
}

fn render(entries: &[(&str, u32)]) -> String {
    let mut s = String::from(
        "# golden_trace checksums — regenerate with UPDATE_GOLDEN=1 \
         cargo test --test golden_trace\n");
    for (name, crc) in entries {
        writeln!(s, "{name}=0x{crc:08X}").unwrap();
    }
    s
}

/// The golden comparison itself: one checksum per optimizer family on
/// the reference configuration (scalar backend, scalar kernels).
#[test]
fn golden_trace_checksums() {
    let entries: Vec<(&str, u32)> = FAMILIES
        .iter()
        .map(|&(opt, name)| {
            (name,
             run_trace(opt, Variant::Flash, BackendKind::Scalar, 0,
                       KernelKind::Scalar, true, false, false))
        })
        .collect();

    // in-process determinism is a precondition for pinning anything
    for &(opt, name) in &FAMILIES {
        let again = run_trace(opt, Variant::Flash, BackendKind::Scalar,
                              0, KernelKind::Scalar, true, false, false);
        let first = entries.iter().find(|(n, _)| *n == name).unwrap().1;
        assert_eq!(first, again, "{name}: trace not deterministic");
        // gradient-release streaming must reproduce the *pinned* CRCs,
        // not merely be self-consistent: same bits as the batch step
        let streamed = run_trace(opt, Variant::Flash,
                                 BackendKind::Scalar, 0,
                                 KernelKind::Scalar, true, true, false);
        assert_eq!(first, streamed,
                   "{name}: streaming step drifted off the pinned \
                    batch checksum");
    }

    let path = golden_path();
    let update = std::env::var("UPDATE_GOLDEN").as_deref() == Ok("1");
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, render(&entries)).unwrap();
        for (name, crc) in &entries {
            println!("golden_trace: {name}=0x{crc:08X}");
        }
        if update {
            println!("golden_trace: regenerated {}", path.display());
        } else {
            println!(
                "golden_trace: seeded {} — commit it to pin these \
                 checksums across PRs",
                path.display());
        }
        return;
    }

    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    for (name, crc) in &entries {
        let want = text
            .lines()
            .find_map(|l| l.strip_prefix(&format!("{name}=")))
            .unwrap_or_else(|| {
                panic!(
                    "{} has no entry for {name}; regenerate with \
                     UPDATE_GOLDEN=1",
                    path.display())
            })
            .trim();
        let got = format!("0x{crc:08X}");
        assert_eq!(
            want, got,
            "{name}: golden checksum drifted ({want} pinned, {got} \
             computed).  Some layer changed the numerics — if the \
             change is intentional, rerun with UPDATE_GOLDEN=1 and \
             commit the new {}",
            path.display());
    }
}

/// The checksum must not depend on which engine computed it: kernels
/// (scalar vs auto/AVX2), backend (sequential vs thread pool), the
/// fused single pass vs the tiled mirror, the batch step vs the
/// gradient-release streaming step, and shard-owner execution
/// (`shard_state`) all produce the same bits — for
/// **every variant**, the fp32-resident layouts included now that the
/// fused kernels cover all 21 (optimizer, variant) pairs.  Only the
/// `flash` families are pinned in the golden file; the other variants
/// (the nibble-packed `quant4`/`mixed84` included) are asserted
/// engine-invariant in-process, which is the property the new
/// coverage must uphold.
#[test]
fn golden_trace_is_engine_invariant() {
    const ALL_VARIANTS: [Variant; 7] = [
        Variant::Reference,
        Variant::Flash,
        Variant::WeightSplit,
        Variant::OptQuant,
        Variant::NoCompand,
        Variant::Quant4,
        Variant::Mixed84,
    ];
    for &(opt, name) in &FAMILIES {
        for variant in ALL_VARIANTS {
            let what = format!("{name}/{variant}");
            let reference = run_trace(opt, variant, BackendKind::Scalar,
                                      0, KernelKind::Scalar, true,
                                      false, false);
            let tiled = run_trace(opt, variant, BackendKind::Scalar, 0,
                                  KernelKind::Scalar, false, false,
                                  false);
            assert_eq!(reference, tiled, "{what}: fused vs tiled");
            let auto = run_trace(opt, variant, BackendKind::Scalar, 0,
                                 KernelKind::Auto, true, false, false);
            assert_eq!(reference, auto,
                       "{what}: scalar vs auto kernels");
            let par = run_trace(opt, variant, BackendKind::Parallel, 3,
                                KernelKind::Auto, true, false, false);
            assert_eq!(reference, par,
                       "{what}: sequential vs parallel");
            // gradient-release streaming spans the same axes: fused
            // and tiled kernels, sequential and parallel backends all
            // reproduce the batch bits bucket-by-bucket
            let s_fused = run_trace(opt, variant, BackendKind::Scalar,
                                    0, KernelKind::Scalar, true, true,
                                    false);
            assert_eq!(reference, s_fused,
                       "{what}: streaming (fused) vs batch");
            let s_tiled = run_trace(opt, variant, BackendKind::Scalar,
                                    0, KernelKind::Scalar, false, true,
                                    false);
            assert_eq!(reference, s_tiled,
                       "{what}: streaming (tiled) vs batch");
            let s_par = run_trace(opt, variant, BackendKind::Parallel,
                                  3, KernelKind::Auto, true, true,
                                  false);
            assert_eq!(reference, s_par,
                       "{what}: streaming (parallel) vs batch");
            // shard-owner execution is one more engine axis: batch and
            // streaming sharded runs on the pool, plus the sequential
            // no-op fallback, all land on the same pinned checksum
            let sh_par = run_trace(opt, variant, BackendKind::Parallel,
                                   3, KernelKind::Auto, true, false,
                                   true);
            assert_eq!(reference, sh_par,
                       "{what}: sharded (parallel) vs batch");
            let sh_stream = run_trace(opt, variant,
                                      BackendKind::Parallel, 3,
                                      KernelKind::Auto, true, true,
                                      true);
            assert_eq!(reference, sh_stream,
                       "{what}: sharded streaming vs batch");
            let sh_seq = run_trace(opt, variant, BackendKind::Scalar,
                                   0, KernelKind::Scalar, true, false,
                                   true);
            assert_eq!(reference, sh_seq,
                       "{what}: sharded fallback (sequential) vs batch");
        }
    }
}
