//! Tracker-pinned peak bytes/param for batch vs streaming steps.
//!
//! The paper's headline numbers — FlashAdamW 7 bytes/param in batch
//! mode, 5 with gradient release (Table 1) — are asserted here as
//! *measured* tracker high-water marks per (optimizer, variant) pair,
//! not as arithmetic: the optimizer is stepped for real with the same
//! accounting the `Trainer` uses, and the observed
//! Params + OptimState + Gradients peak is compared against
//! `memory::per_param`.  A regression that quietly re-materializes the
//! full gradient vector in streaming mode (or grows a state buffer)
//! fails with the offending category breakdown printed.
//!
//! Epsilons are analytic, not slop:
//! * the f16 group scales cost `2/GROUP` bytes/param per quantized
//!   buffer (≤ `4/GROUP` = 0.125 total), which is why "7" measures as
//!   7.125 and "5" as 5.125;
//! * streaming keeps exactly one bucket of gradient live, i.e.
//!   `bucket · grad_bytes / n` bytes/param;
//! * the unaligned case pays GROUP padding on the persistent state.

use flashtrain::config::{BackendKind, OptKind, TrainConfig, Variant};
use flashtrain::formats::{bf16, GROUP};
use flashtrain::memory::per_param;
use flashtrain::memory::tracker::{Category, Tracker};
use flashtrain::optim::{FlashOptimizer, GroupSpec, HyperDefaults};
use flashtrain::util::rng::Rng;

const ALL_OPTS: [OptKind; 3] =
    [OptKind::Sgd, OptKind::AdamW, OptKind::Lion];
const ALL_VARIANTS: [Variant; 7] = [
    Variant::Reference,
    Variant::Flash,
    Variant::WeightSplit,
    Variant::OptQuant,
    Variant::NoCompand,
    Variant::Quant4,
    Variant::Mixed84,
];

/// Aligned config: bucket divides n, n is a GROUP multiple, so the
/// measured numbers match the analytic model exactly.
const N: usize = 256 * GROUP; // 8192
const BUCKET: usize = 16 * GROUP; // 512

fn grad(n: usize, variant: Variant, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let x = rng.normal() as f32 * 0.01;
            if variant.splits_weights() {
                bf16::round_f32_to_bf16(x)
            } else {
                x
            }
        })
        .collect()
}

fn grad_elem_bytes(variant: Variant) -> u64 {
    if variant.splits_weights() {
        2
    } else {
        4
    }
}

/// Peak bytes/param over the categories the paper's Table 1 counts
/// (activations and transients are model-side, not optimizer-side).
fn measured_bpp(tracker: &Tracker, count: usize) -> f64 {
    let peak = tracker.category_peak(Category::Params)
        + tracker.category_peak(Category::OptimState)
        + tracker.category_peak(Category::Gradients);
    peak as f64 / count as f64
}

fn breakdown_msg(tracker: &Tracker, count: usize) -> String {
    let mut s = String::new();
    for (cat, bytes) in tracker.summary() {
        s.push_str(&format!("\n  {:>12}: {:>10} B  ({:.4} B/param)",
                            cat.name(), bytes,
                            bytes as f64 / count as f64));
        for (name, b) in tracker.category_entries(cat) {
            s.push_str(&format!("\n      live {name}: {b} B"));
        }
    }
    s
}

/// Step `count` params twice with trainer-equivalent tracker
/// accounting and return (tracker, peak bytes/param).
fn run_mode(opt: OptKind, variant: Variant, streaming: bool,
            count: usize, bucket: usize) -> (Tracker, f64) {
    let mut rng = Rng::new(0x9EA7 ^ count as u64);
    let theta0: Vec<f32> =
        (0..count).map(|_| rng.normal() as f32 * 0.1).collect();
    let cfg = TrainConfig {
        optimizer: opt,
        ..Default::default()
    };
    let mut fo = FlashOptimizer::native(
        opt, variant, bucket, &theta0, GroupSpec::single(count),
        HyperDefaults::of(&cfg), BackendKind::Scalar, 0)
        .unwrap();
    let mut tracker = Tracker::new();
    fo.track(&mut tracker);
    let gbytes = grad_elem_bytes(variant);
    for t in 1..=2usize {
        let g = grad(count, variant, 0x6E0D + t as u64);
        if streaming {
            // mirror of Trainer's streaming branch: the live bucket
            // and the staging double-buffer are metered by the stream
            // itself and folded in as transients
            let stats =
                fo.step_streaming(&g, 1e-3, t, |_, _| {}).unwrap();
            tracker.note_transient(Category::Gradients,
                                   "stream_live_bucket",
                                   stats.peak_live_grad_bytes);
            tracker.note_transient(Category::Transient, "stream_staging",
                                   stats.peak_staging_bytes);
        } else {
            // mirror of the batch branch: the full reduced gradient is
            // persistent gradient memory across the whole step
            tracker.alloc(Category::Gradients, "full_grad",
                          count as u64 * gbytes);
            fo.step(&g, 1e-3, t, |_, _| {}).unwrap();
            tracker.free(Category::Gradients, "full_grad");
        }
    }
    let bpp = measured_bpp(&tracker, count);
    (tracker, bpp)
}

/// f16 group-scale overhead: ≤ two quantized buffers at 2 B per GROUP.
const SCALES_EPS: f64 = 4.0 / GROUP as f64; // 0.125

#[test]
fn adamw_flash_pins_the_paper_headline_numbers() {
    let one_bucket = (BUCKET as u64 * grad_elem_bytes(Variant::Flash))
        as f64 / N as f64;

    let (tb, batch) =
        run_mode(OptKind::AdamW, Variant::Flash, false, N, BUCKET);
    assert!(batch <= 7.0 + SCALES_EPS + 1e-9,
            "adamw/flash batch peak {batch:.4} B/param exceeds the \
             7-byte row (+{SCALES_EPS} scales):{}",
            breakdown_msg(&tb, N));
    assert!(batch >= 7.0,
            "adamw/flash batch peak {batch:.4} under-measures the \
             7-byte row — tracker lost a category:{}",
            breakdown_msg(&tb, N));

    let (ts, stream) =
        run_mode(OptKind::AdamW, Variant::Flash, true, N, BUCKET);
    assert!(stream <= 5.0 + SCALES_EPS + one_bucket + 1e-9,
            "adamw/flash streaming peak {stream:.4} B/param exceeds \
             the 5-byte row (+{SCALES_EPS} scales +{one_bucket:.4} \
             one-bucket epsilon):{}",
            breakdown_msg(&ts, N));
    assert!(stream >= 5.0,
            "adamw/flash streaming peak {stream:.4} under-measures the \
             5-byte row — tracker lost a category:{}",
            breakdown_msg(&ts, N));
    println!("adamw/flash: batch {batch:.4} B/param, streaming \
              {stream:.4} B/param (one-bucket eps {one_bucket:.4})");
}

/// The 4-bit layouts' headline rows, measured like the paper's: AdamW
/// with both moments nibble-packed peaks at 6 B/param in batch mode
/// (2 θ′ + 1 ρ + 0.5 m + 0.5 v + 2 grad) and 4 with gradient release
/// — a full byte per moment under flash — with `mixed84` strictly
/// between the two.
#[test]
fn adamw_quant4_pins_the_4bit_headline_numbers() {
    let one_bucket = (BUCKET as u64 * grad_elem_bytes(Variant::Quant4))
        as f64 / N as f64;

    let (tb, batch) =
        run_mode(OptKind::AdamW, Variant::Quant4, false, N, BUCKET);
    assert!(batch <= 6.0 + SCALES_EPS + 1e-9,
            "adamw/quant4 batch peak {batch:.4} B/param exceeds the \
             6-byte row (+{SCALES_EPS} scales):{}",
            breakdown_msg(&tb, N));
    assert!(batch >= 6.0,
            "adamw/quant4 batch peak {batch:.4} under-measures the \
             6-byte row — tracker lost a category:{}",
            breakdown_msg(&tb, N));

    let (ts, stream) =
        run_mode(OptKind::AdamW, Variant::Quant4, true, N, BUCKET);
    assert!(stream <= 4.0 + SCALES_EPS + one_bucket + 1e-9,
            "adamw/quant4 streaming peak {stream:.4} B/param exceeds \
             the 4-byte row (+{SCALES_EPS} scales +{one_bucket:.4} \
             one-bucket epsilon):{}",
            breakdown_msg(&ts, N));
    assert!(stream >= 4.0,
            "adamw/quant4 streaming peak {stream:.4} under-measures \
             the 4-byte row — tracker lost a category:{}",
            breakdown_msg(&ts, N));

    // ordering across the quantized family: quant4 < mixed84 < flash
    let (_, mixed) =
        run_mode(OptKind::AdamW, Variant::Mixed84, true, N, BUCKET);
    let (_, flash) =
        run_mode(OptKind::AdamW, Variant::Flash, true, N, BUCKET);
    assert!(stream < mixed && mixed < flash,
            "streaming peaks must order quant4 {stream:.4} < mixed84 \
             {mixed:.4} < flash {flash:.4}");
    println!("adamw/quant4: batch {batch:.4} B/param, streaming \
              {stream:.4} B/param (mixed84 {mixed:.4}, flash \
              {flash:.4})");
}

#[test]
fn all_pairs_match_the_analytic_model() {
    for &opt in &ALL_OPTS {
        for &variant in &ALL_VARIANTS {
            for streaming in [false, true] {
                let (tracker, bpp) =
                    run_mode(opt, variant, streaming, N, BUCKET);
                let one_bucket = if streaming {
                    (BUCKET as u64 * grad_elem_bytes(variant)) as f64
                        / N as f64
                } else {
                    0.0
                };
                let expected = per_param(opt, variant, streaming)
                    .total()
                    + one_bucket;
                let what = format!("{}/{} {}", opt.name(),
                                   variant.name(),
                                   if streaming { "streaming" }
                                   else { "batch" });
                assert!((bpp - expected).abs() < 0.01,
                        "{what}: measured {bpp:.4} B/param vs analytic \
                         {expected:.4}:{}",
                        breakdown_msg(&tracker, N));
            }
        }
    }
}

#[test]
fn unaligned_count_stays_within_padding_epsilon() {
    // 700 params, bucket 128 -> padded state of 768: persistent bytes
    // are paid on the padded length, gradients only on the real one
    let count = 700;
    let bucket = 4 * GROUP;
    let padded = count.next_multiple_of(bucket);
    let pad_factor = padded as f64 / count as f64;
    for streaming in [false, true] {
        let (tracker, bpp) = run_mode(OptKind::AdamW, Variant::Flash,
                                      streaming, count, bucket);
        let gb = grad_elem_bytes(Variant::Flash);
        // streaming gradient peak: one padded bucket + held edges
        let grad_bpp = if streaming {
            (bucket as u64 * gb) as f64 * pad_factor / count as f64
        } else {
            gb as f64
        };
        let bound =
            (5.0 + SCALES_EPS) * pad_factor + grad_bpp + 1e-9;
        assert!(bpp <= bound,
                "unaligned {} peak {bpp:.4} B/param exceeds padded \
                 bound {bound:.4}:{}",
                if streaming { "streaming" } else { "batch" },
                breakdown_msg(&tracker, count));
        if streaming {
            assert!(bpp < 5.0 + SCALES_EPS + 1.0,
                    "streaming must stay near 5 B/param even with \
                     padding: {bpp:.4}");
        }
    }
}

#[test]
fn streaming_never_holds_the_full_gradient() {
    // the defining property of gradient release, asserted on the raw
    // stream stats across every pair: live gradient bytes never reach
    // the full-vector footprint
    for &opt in &ALL_OPTS {
        for &variant in &ALL_VARIANTS {
            let mut rng = Rng::new(0x11FE);
            let theta0: Vec<f32> =
                (0..N).map(|_| rng.normal() as f32 * 0.1).collect();
            let cfg = TrainConfig {
                optimizer: opt,
                ..Default::default()
            };
            let mut fo = FlashOptimizer::native(
                opt, variant, BUCKET, &theta0, GroupSpec::single(N),
                HyperDefaults::of(&cfg), BackendKind::Scalar, 0)
                .unwrap();
            let g = grad(N, variant, 0xF00D);
            let stats =
                fo.step_streaming(&g, 1e-3, 1, |_, _| {}).unwrap();
            let full = N as u64 * grad_elem_bytes(variant);
            let one = BUCKET as u64 * grad_elem_bytes(variant);
            assert_eq!(stats.peak_live_grad_bytes, one,
                       "{}/{}: aligned streaming must hold exactly one \
                        bucket", opt.name(), variant.name());
            assert!(stats.peak_live_grad_bytes < full / 8,
                    "{}/{}: streaming holds {} of {} full-gradient \
                     bytes", opt.name(), variant.name(),
                    stats.peak_live_grad_bytes, full);
        }
    }
}
