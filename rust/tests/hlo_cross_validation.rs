//! Integration: Rust `formats`/`scalar_ref` vs the AOT HLO kernels,
//! executed through PJRT.  These are the ground-truth equivalence tests
//! between Layer 3 and Layers 1/2 (requires `make artifacts`).

use flashtrain::config::{OptKind, TrainConfig, Variant};
use flashtrain::formats::{companding, weight_split, Correction, Target,
                          GROUP};
use flashtrain::optim::{scalar_ref, BucketOptimizer, Hyper, State};
use flashtrain::runtime::literal as lit;
use flashtrain::runtime::{Manifest, Runtime};
use flashtrain::util::rng::Rng;

fn setup() -> Option<(Manifest, Runtime)> {
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping: artifacts not built");
            return None;
        }
    };
    Some((manifest, Runtime::cpu().unwrap()))
}

fn log_uniform(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| (rng.normal() as f32) * (rng.f32() * 30.0 - 20.0).exp2())
        .collect()
}

#[test]
fn split_kernels_bitexact_i8_and_i16() {
    let Some((manifest, rt)) = setup() else { return };
    let n = manifest.kernel_size;
    let mut rng = Rng::new(1);
    let theta = log_uniform(&mut rng, n);

    for (enc_name, dec_name, corr) in [
        ("split_enc_i8", "split_dec_i8", Correction::Int8),
        ("split_enc_i16", "split_dec_i16", Correction::Int16),
    ] {
        let enc = rt.load(&manifest.kernel_artifact(enc_name).unwrap())
            .unwrap();
        let out = enc.run(&[lit::lit_f32(&theta, &[n]).unwrap()]).unwrap();
        let tp_hlo = lit::to_bf16_bits(&out[0]).unwrap();
        for (i, &x) in theta.iter().enumerate() {
            let (tp, rho) = weight_split::compress(x, corr, Target::Bf16);
            assert_eq!(tp, tp_hlo[i], "{enc_name} theta_p at {i}: x={x}");
            let rho_hlo = match corr {
                Correction::Int8 => {
                    lit::to_i8_vec(&out[1]).unwrap()[i] as i32
                }
                Correction::Int16 => {
                    lit::to_i16_vec(&out[1]).unwrap()[i] as i32
                }
            };
            assert_eq!(rho, rho_hlo, "{enc_name} rho at {i}: x={x}");
        }
        // decode round-trip
        let dec = rt.load(&manifest.kernel_artifact(dec_name).unwrap())
            .unwrap();
        let back = dec.run(&[out[0].clone(), out[1].clone()]).unwrap();
        let back_hlo = lit::to_f32_vec(&back[0]).unwrap();
        for (i, &x) in theta.iter().enumerate() {
            let (tp, rho) = weight_split::compress(x, corr, Target::Bf16);
            let mine = weight_split::decompress(tp, rho, corr,
                                                Target::Bf16);
            assert_eq!(mine.to_bits(), back_hlo[i].to_bits(),
                       "{dec_name} at {i}");
        }
    }
}

/// Quantization involves real f32 arithmetic, and XLA CPU compiles it
/// with FMA contraction, so codes can differ by +-1 at rounding
/// boundaries vs our strictly-IEEE Rust mirror.  Scales (pure max +
/// f16 convert) must still be bit-exact; codes must agree within 1 and
/// almost everywhere exactly.
#[test]
fn quant_kernels_match_within_one_code() {
    let Some((manifest, rt)) = setup() else { return };
    let n = manifest.kernel_size;
    let mut rng = Rng::new(2);
    let m: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
    let v: Vec<f32> = m.iter().map(|x| x * x * 3.7).collect();

    let check_i8 = |hlo: &[i8], mine: &[i8], what: &str| {
        let mut off_by_one = 0usize;
        for i in 0..n {
            let d = (hlo[i] as i32 - mine[i] as i32).abs();
            assert!(d <= 1, "{what} at {i}: {} vs {}", hlo[i], mine[i]);
            off_by_one += (d == 1) as usize;
        }
        assert!(off_by_one * 100 < n, "{what}: {off_by_one}/{n} off by 1");
    };
    let check_u8 = |hlo: &[u8], mine: &[u8], what: &str| {
        let mut off_by_one = 0usize;
        for i in 0..n {
            let d = (hlo[i] as i32 - mine[i] as i32).abs();
            assert!(d <= 1, "{what} at {i}: {} vs {}", hlo[i], mine[i]);
            off_by_one += (d == 1) as usize;
        }
        assert!(off_by_one * 100 < n, "{what}: {off_by_one}/{n} off by 1");
    };

    // companded momentum
    let mq = rt.load(&manifest.kernel_artifact("mq_enc").unwrap()).unwrap();
    let out = mq.run(&[lit::lit_f32(&m, &[n]).unwrap()]).unwrap();
    let mut q = vec![0i8; n];
    let mut s = vec![0u16; n / GROUP];
    companding::quant_momentum(&m, &mut q, &mut s);
    check_i8(&lit::to_i8_vec(&out[0]).unwrap(), &q, "mq codes");
    assert_eq!(s, lit::to_f16_bits(&out[1]).unwrap(), "mq scales");

    // dequant: one f32 ulp tolerance (FMA contraction in mp * s)
    let md = rt.load(&manifest.kernel_artifact("mq_dec").unwrap()).unwrap();
    let back = md.run(&[out[0].clone(), out[1].clone()]).unwrap();
    let hlo_q = lit::to_i8_vec(&out[0]).unwrap();
    let mut mine = vec![0f32; n];
    companding::dequant_momentum(&hlo_q, &s, &mut mine);
    let hlo = lit::to_f32_vec(&back[0]).unwrap();
    for i in 0..n {
        // XLA CPU fast-math may turn /127 into *reciprocal: a few ulps
        let rel = (mine[i] - hlo[i]).abs()
            / mine[i].abs().max(f32::MIN_POSITIVE);
        assert!(rel < 1e-6, "mq_dec {i}: {} vs {}", mine[i], hlo[i]);
    }

    // companded variance
    let vq = rt.load(&manifest.kernel_artifact("vq_enc").unwrap()).unwrap();
    let out = vq.run(&[lit::lit_f32(&v, &[n]).unwrap()]).unwrap();
    let mut qv = vec![0u8; n];
    companding::quant_variance(&v, &mut qv, &mut s);
    check_u8(&lit::to_u8_vec(&out[0]).unwrap(), &qv, "vq codes");
    assert_eq!(s, lit::to_f16_bits(&out[1]).unwrap(), "vq scales");

    // linear ablations
    let ml = rt.load(&manifest.kernel_artifact("mq_lin_enc").unwrap())
        .unwrap();
    let out = ml.run(&[lit::lit_f32(&m, &[n]).unwrap()]).unwrap();
    companding::quant_momentum_linear(&m, &mut q, &mut s);
    check_i8(&lit::to_i8_vec(&out[0]).unwrap(), &q, "mq_lin codes");
    let vl = rt.load(&manifest.kernel_artifact("vq_lin_enc").unwrap())
        .unwrap();
    let out = vl.run(&[lit::lit_f32(&v, &[n]).unwrap()]).unwrap();
    companding::quant_variance_linear(&v, &mut qv, &mut s);
    check_u8(&lit::to_u8_vec(&out[0]).unwrap(), &qv, "vq_lin codes");
}

/// The fused HLO step and the pure-Rust scalar mirror must agree for
/// every optimizer/variant combination.  XLA CPU contracts mul+add into
/// FMA, so quantized codes may differ by +-1 at rounding boundaries and
/// f32 values by ~1 ulp; we check tight numeric agreement of the
/// *reconstructed* master weights and states rather than raw bits.
#[test]
fn fused_steps_match_scalar_mirror() {
    let Some((manifest, rt)) = setup() else { return };
    let bucket = *manifest.buckets.keys().next().unwrap();
    let mut rng = Rng::new(3);

    for (opt, variant) in [
        (OptKind::AdamW, Variant::Flash),
        (OptKind::AdamW, Variant::Reference),
        (OptKind::AdamW, Variant::WeightSplit),
        (OptKind::AdamW, Variant::OptQuant),
        (OptKind::AdamW, Variant::NoCompand),
        (OptKind::Sgd, Variant::Flash),
        (OptKind::Sgd, Variant::Reference),
        (OptKind::Lion, Variant::Flash),
        (OptKind::Lion, Variant::Reference),
    ] {
        let theta0: Vec<f32> =
            (0..bucket).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut opt_exec = BucketOptimizer::new(&rt, &manifest, opt,
                                                variant, bucket, &theta0)
            .unwrap();
        let mut mirror = State::init(&theta0, bucket, opt, variant);

        let cfg = TrainConfig {
            optimizer: opt,
            variant,
            ..Default::default()
        };
        for t in 1..=3 {
            let g: Vec<f32> = (0..bucket)
                .map(|_| {
                    let x = rng.normal() as f32 * 0.01;
                    if variant.splits_weights() {
                        flashtrain::formats::bf16::round_f32_to_bf16(x)
                    } else {
                        x
                    }
                })
                .collect();
            let h = Hyper::for_step(&cfg, 1e-3, t);
            opt_exec.step_bucket(0, &g, &h).unwrap();
            scalar_ref::step_state(&mut mirror, &g, opt, variant, &h);
        }

        // reconstructed master weights: relative agreement well below
        // the ~1e-3 update scale (lr=1e-3, 3 steps)
        let wa = opt_exec.state.master_weights();
        let wb = mirror.master_weights();
        let mut worst = 0f64;
        for (p, q) in wa.iter().zip(&wb) {
            let d = ((p - q).abs() / (q.abs().max(1e-2))) as f64;
            worst = worst.max(d);
        }
        assert!(worst < 2e-4, "{opt}/{variant} weight drift {worst}");

        // dequantized momentum (and variance) agreement
        let nocomp = variant == Variant::NoCompand;
        let ma = opt_exec.state.momentum_f32(nocomp).unwrap();
        let mb = mirror.momentum_f32(nocomp).unwrap();
        let scale = mb.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1e-6);
        let mut worst = 0f32;
        for (p, q) in ma.iter().zip(&mb) {
            worst = worst.max((p - q).abs() / scale);
        }
        assert!(worst < 0.02, "{opt}/{variant} momentum drift {worst}");
        if let (Some(va), Some(vb)) = (opt_exec.state.variance_f32(nocomp),
                                       mirror.variance_f32(nocomp)) {
            let scale = vb.iter().fold(0f32, |a, &b| a.max(b)).max(1e-12);
            let mut worst = 0f32;
            for (p, q) in va.iter().zip(&vb) {
                worst = worst.max((p - q).abs() / scale);
            }
            assert!(worst < 0.02, "{opt}/{variant} variance drift {worst}");
        }
    }
}
