//! Offline stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! The container has no PJRT / XLA shared library, so this in-tree crate
//! keeps the same API shape flashtrain uses while splitting it in two:
//!
//! * **Fully functional, pure Rust:** `Literal` (typed shape + raw host
//!   bytes), creation from untyped data, typed extraction, and the
//!   bf16/f16 → f32 upcasts used by `runtime::literal`.  Literal
//!   marshalling therefore behaves identically with or without a real
//!   XLA build.
//! * **Stubbed:** `PjRtClient::compile` and executable execution return
//!   a clear "no PJRT runtime linked" error.  Everything that needs the
//!   AOT HLO executables reports this at the point of use; the native
//!   Rust step backends (`flashtrain::backend`) never reach it.

use std::fmt;

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_runtime<T>() -> Result<T> {
    Err(Error(
        "no PJRT runtime linked into this build; the AOT HLO path is \
         unavailable — use the native backends (backend = \"scalar\" | \
         \"parallel\") or link a real xla crate"
            .to_string(),
    ))
}

// ---------------------------------------------------------------------------
// element types
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

/// Target-type token accepted by [`Literal::convert`] (mirrors xla-rs,
/// where `ElementType::primitive_type()` yields the conversion target).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrimitiveType(ElementType);

impl ElementType {
    pub fn primitive_type(self) -> PrimitiveType {
        PrimitiveType(self)
    }

    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16
            | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
}

macro_rules! native {
    ($t:ty, $e:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $e;
        }
    };
}

native!(i8, ElementType::S8);
native!(i16, ElementType::S16);
native!(i32, ElementType::S32);
native!(i64, ElementType::S64);
native!(u8, ElementType::U8);
native!(u16, ElementType::U16);
native!(u32, ElementType::U32);
native!(u64, ElementType::U64);
native!(f32, ElementType::F32);
native!(f64, ElementType::F64);

// ---------------------------------------------------------------------------
// literals
// ---------------------------------------------------------------------------

/// Host-side literal: a typed dense array (or tuple of them) with raw
/// little-endian bytes, matching XLA's host layout.
#[derive(Clone, Debug)]
pub enum Literal {
    Array {
        ty: ElementType,
        dims: Vec<usize>,
        data: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let count: usize = dims.iter().product();
        let want = count * ty.byte_size();
        if data.len() != want {
            return Err(Error(format!(
                "literal data size mismatch: {} bytes for {count} x \
                 {ty:?} (want {want})",
                data.len()
            )));
        }
        Ok(Literal::Array { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        match self {
            Literal::Array { ty, .. } => Ok(*ty),
            Literal::Tuple(_) => {
                Err(Error("tuple literal has no element type".into()))
            }
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { dims, .. } => dims.iter().product(),
            Literal::Tuple(parts) => parts.len(),
        }
    }

    /// Extract as a typed vector; the requested type must match exactly.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return Err(Error(format!(
                        "literal type mismatch: have {ty:?}, asked for \
                         {:?}",
                        T::TY
                    )));
                }
                let n = data.len() / std::mem::size_of::<T>();
                let mut out: Vec<T> = Vec::with_capacity(n);
                // byte-wise copy into the (aligned) destination buffer;
                // the source Vec<u8> has no alignment guarantee
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        data.as_ptr(),
                        out.as_mut_ptr() as *mut u8,
                        n * std::mem::size_of::<T>(),
                    );
                    out.set_len(n);
                }
                Ok(out)
            }
            Literal::Tuple(_) => {
                Err(Error("cannot to_vec a tuple literal".into()))
            }
        }
    }

    /// Element-type conversion.  The stub supports what flashtrain uses:
    /// exact upcasts from bf16/f16 (and identity) to f32.
    pub fn convert(&self, to: PrimitiveType) -> Result<Literal> {
        let PrimitiveType(to) = to;
        let (ty, dims, data) = match self {
            Literal::Array { ty, dims, data } => (*ty, dims, data),
            Literal::Tuple(_) => {
                return Err(Error("cannot convert a tuple literal".into()))
            }
        };
        if ty == to {
            return Ok(self.clone());
        }
        match (ty, to) {
            (ElementType::Bf16, ElementType::F32) => {
                let out = half_bits(data)
                    .map(|b| f32::from_bits((b as u32) << 16))
                    .collect::<Vec<f32>>();
                Literal::create_from_shape_and_untyped_data(
                    ElementType::F32, dims, f32_bytes(&out))
            }
            (ElementType::F16, ElementType::F32) => {
                let out = half_bits(data)
                    .map(f16_bits_to_f32)
                    .collect::<Vec<f32>>();
                Literal::create_from_shape_and_untyped_data(
                    ElementType::F32, dims, f32_bytes(&out))
            }
            (from, to) => Err(Error(format!(
                "stub convert {from:?} -> {to:?} unsupported"
            ))),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            lit @ Literal::Array { .. } => Ok(vec![lit]),
        }
    }
}

fn half_bits(data: &[u8]) -> impl Iterator<Item = u16> + '_ {
    data.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]]))
}

fn f32_bytes(v: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
    }
}

/// Exact IEEE binary16 -> binary32 upcast (subnormals, inf, NaN).
fn f16_bits_to_f32(bits: u16) -> f32 {
    let sign = ((bits as u32) & 0x8000) << 16;
    let exp = ((bits >> 10) & 0x1F) as u32;
    let man = (bits & 0x3FF) as u32;
    if exp == 0x1F {
        // inf / nan
        let m = if man == 0 { 0 } else { 0x0040_0000 | (man << 13) };
        return f32::from_bits(sign | 0x7F80_0000 | m);
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // +-0
        }
        // subnormal: value = man * 2^-24; renormalize
        let shift = man.leading_zeros() - 21; // make bit 10 the implicit 1
        let man_norm = (man << shift) & 0x3FF;
        let e = 1i32 - shift as i32; // f16 exponent after normalization
        let exp32 = (e - 15 + 127) as u32;
        return f32::from_bits(sign | (exp32 << 23) | (man_norm << 13));
    }
    let exp32 = exp + 127 - 15;
    f32::from_bits(sign | (exp32 << 23) | (man << 13))
}

// ---------------------------------------------------------------------------
// PJRT stubs
// ---------------------------------------------------------------------------

pub struct HloModuleProto;

impl HloModuleProto {
    /// The stub checks the artifact file is readable (so missing
    /// artifacts still produce the right error) but does not parse it.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub (no PJRT linked)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable>
    {
        no_runtime()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_runtime()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        no_runtime()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let v = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> =
            v.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[3], &bytes)
            .unwrap();
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), v);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn size_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32, &[3], &[0u8; 8])
            .is_err());
    }

    #[test]
    fn bf16_convert_exact() {
        // bf16 bits are the top 16 bits of f32
        let vals = [1.0f32, -0.5, 3.0, 65536.0];
        let bits: Vec<u8> = vals
            .iter()
            .flat_map(|x| ((x.to_bits() >> 16) as u16).to_le_bytes())
            .collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::Bf16, &[4], &bits)
            .unwrap();
        let out = lit
            .convert(ElementType::F32.primitive_type())
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        assert_eq!(out, vals);
    }

    #[test]
    fn f16_convert_covers_edge_cases() {
        // (f16 bits, expected f32)
        let cases: [(u16, f32); 8] = [
            (0x0000, 0.0),
            (0x8000, -0.0),
            (0x3C00, 1.0),
            (0xC000, -2.0),
            (0x7BFF, 65504.0),        // max finite
            (0x0400, 6.103515625e-5), // min normal 2^-14
            (0x0001, 5.960464477539063e-8), // min subnormal 2^-24
            (0x03FF, 6.097555160522461e-5), // max subnormal
        ];
        for (bits, want) in cases {
            let got = f16_bits_to_f32(bits);
            assert_eq!(got.to_bits(), want.to_bits(), "bits {bits:#06x}");
        }
        assert!(f16_bits_to_f32(0x7C00).is_infinite());
        assert!(f16_bits_to_f32(0xFC00).is_infinite());
        assert!(f16_bits_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn execute_reports_missing_runtime() {
        let client = PjRtClient::cpu().unwrap();
        let err = client.compile(&XlaComputation::from_proto(
            &HloModuleProto)).unwrap_err();
        assert!(err.to_string().contains("no PJRT runtime"));
    }
}
