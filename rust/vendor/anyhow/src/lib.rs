//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build runs with no network access, so this in-tree shim provides
//! exactly the surface flashtrain uses: `Error` (a context chain),
//! `Result<T>`, the `Context` extension trait on `Result`/`Option`, and
//! the `anyhow!`/`bail!` macros.  Like the real crate, `Error` does NOT
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and `?`) legal.

use std::fmt;

/// Error with a context chain, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, outermost first, like anyhow
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // include source chain if present
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on fallible values.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E>
    for Result<T, E>
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T, Error>
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chain_formats() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert!(format!("{e:#}").contains("missing value"));
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        let e = f(0).unwrap_err();
        assert!(e.to_string().contains("zero not allowed"));
        let e2 = anyhow!("plain {}", 7);
        assert_eq!(e2.to_string(), "plain 7");
    }
}
