#!/usr/bin/env python3
"""Render a bench artifact as a GitHub job-summary markdown table.

Dispatches on the document's `bench` field:
* `kernel_hotpath` (BENCH_kernels.json, schema v3) — the
  fused-vs-tiled section;
* `train_step` (BENCH_train.json, schema v1) — batch vs
  gradient-release streaming step time and peak bytes/param.

Usage: bench_summary.py BENCH_<name>.json >> "$GITHUB_STEP_SUMMARY"

Keeps zero dependencies (stdlib json only) so the CI step is a single
python3 invocation on the stock runner image.
"""

import json
import sys


def fmt_time(seconds):
    if seconds < 1e-6:
        return f"{seconds * 1e9:.0f} ns"
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f} ms"
    return f"{seconds:.3f} s"


def render_kernels(doc):
    schema = doc.get("schema_version")
    rows = doc.get("fused", [])
    print("## fused single-pass vs tiled three-pass")
    print()
    print(
        f"schema v{schema:g} · {doc.get('step_elements'):,} params · "
        f"avx2_detected={str(doc.get('avx2_detected')).lower()} · "
        f"check={str(doc.get('check')).lower()}"
    )
    print()
    print("| optimizer/variant | kernels | fused | tiled | speedup |"
          " GB/s fused | GB/s tiled |")
    print("|---|---|---|---|---|---|---|")
    for e in rows:
        pair = f"{e['optimizer']}/{e['variant']}"
        print(
            f"| {pair} | {e['kernels']} "
            f"| {fmt_time(e['fused_median_s'])} "
            f"| {fmt_time(e['tiled_median_s'])} "
            f"| {e['speedup']:.2f}x "
            f"| {e['fused_gb_per_s']:.2f} "
            f"| {e['tiled_gb_per_s']:.2f} |"
        )
    if not rows:
        print()
        print("_no fused rows in the bench output_")

    pairs = {(e["optimizer"], e["variant"]) for e in rows}
    print()
    print(f"{len(rows)} rows · {len(pairs)} distinct (optimizer, "
          f"variant) pairs (universe: 15)")


def render_train(doc):
    schema = doc.get("schema_version")
    rows = doc.get("rows", [])
    print("## train step: batch vs gradient-release streaming")
    print()
    print(
        f"schema v{schema:g} · {doc.get('params'):,} params · "
        f"bucket {doc.get('bucket'):,} · "
        f"{doc.get('threads')} threads · "
        f"check={str(doc.get('check')).lower()}"
    )
    print()
    by_pair = {}
    for e in rows:
        pair = f"{e['optimizer']}/{e['variant']}"
        by_pair.setdefault(pair, {})[e["mode"]] = e
    print("| optimizer/variant | batch | streaming | step overhead |"
          " peak B/param batch | peak B/param streaming |")
    print("|---|---|---|---|---|---|")
    for pair, modes in by_pair.items():
        b, s = modes.get("batch"), modes.get("streaming")
        if not b or not s:
            print(f"| {pair} | _missing a mode_ | | | | |")
            continue
        over = s["median_s"] / b["median_s"] - 1.0
        print(
            f"| {pair} | {fmt_time(b['median_s'])} "
            f"| {fmt_time(s['median_s'])} "
            f"| {over:+.1%} "
            f"| {b['peak_bytes_per_param']:.3f} "
            f"| {s['peak_bytes_per_param']:.3f} |"
        )
    if not rows:
        print()
        print("_no rows in the bench output_")
    print()
    print(f"{len(rows)} rows · {len(by_pair)} (optimizer, variant) "
          f"pairs × 2 modes")


def main():
    if len(sys.argv) != 2:
        sys.exit("usage: bench_summary.py BENCH_<name>.json")
    with open(sys.argv[1], encoding="utf-8") as f:
        doc = json.load(f)

    bench = doc.get("bench")
    if bench == "train_step":
        render_train(doc)
    elif bench == "kernel_hotpath":
        render_kernels(doc)
    else:
        sys.exit(f"unknown bench document: {bench!r}")


if __name__ == "__main__":
    main()
