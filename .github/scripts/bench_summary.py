#!/usr/bin/env python3
"""Render a bench artifact as a GitHub job-summary markdown table.

Dispatches on the document's `bench` field:
* `kernel_hotpath` (BENCH_kernels.json, schema v3) — the
  fused-vs-tiled section;
* `train_step` (BENCH_train.json, schema v1) — batch vs
  gradient-release streaming vs shard-owner sharded step time and
  peak bytes/param;
* `checkpoint` (BENCH_checkpoint.json, schema v2) — serial vs
  shard-parallel checkpoint save/load throughput plus on-disk state
  size per layout (`state_files`).

Usage: bench_summary.py BENCH_<name>.json >> "$GITHUB_STEP_SUMMARY"

Keeps zero dependencies (stdlib json only) so the CI step is a single
python3 invocation on the stock runner image.
"""

import json
import sys


def fmt_time(seconds):
    if seconds < 1e-6:
        return f"{seconds * 1e9:.0f} ns"
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f} ms"
    return f"{seconds:.3f} s"


def render_kernels(doc):
    schema = doc.get("schema_version")
    rows = doc.get("fused", [])
    print("## fused single-pass vs tiled three-pass")
    print()
    print(
        f"schema v{schema:g} · {doc.get('step_elements'):,} params · "
        f"avx2_detected={str(doc.get('avx2_detected')).lower()} · "
        f"check={str(doc.get('check')).lower()}"
    )
    print()
    print("| optimizer/variant | kernels | fused | tiled | speedup |"
          " GB/s fused | GB/s tiled |")
    print("|---|---|---|---|---|---|---|")
    for e in rows:
        pair = f"{e['optimizer']}/{e['variant']}"
        print(
            f"| {pair} | {e['kernels']} "
            f"| {fmt_time(e['fused_median_s'])} "
            f"| {fmt_time(e['tiled_median_s'])} "
            f"| {e['speedup']:.2f}x "
            f"| {e['fused_gb_per_s']:.2f} "
            f"| {e['tiled_gb_per_s']:.2f} |"
        )
    if not rows:
        print()
        print("_no fused rows in the bench output_")

    pairs = {(e["optimizer"], e["variant"]) for e in rows}
    print()
    print(f"{len(rows)} rows · {len(pairs)} distinct (optimizer, "
          f"variant) pairs (universe: 21)")


def render_train(doc):
    schema = doc.get("schema_version")
    rows = doc.get("rows", [])
    print("## train step: batch vs streaming vs sharded")
    print()
    print(
        f"schema v{schema:g} · {doc.get('params'):,} params · "
        f"bucket {doc.get('bucket'):,} · "
        f"{doc.get('threads')} threads · "
        f"check={str(doc.get('check')).lower()}"
    )
    print()
    by_pair = {}
    for e in rows:
        pair = f"{e['optimizer']}/{e['variant']}"
        by_pair.setdefault(pair, {})[e["mode"]] = e
    print("| optimizer/variant | batch | streaming | sharded |"
          " sharded speedup |"
          " peak B/param batch | peak B/param streaming |")
    print("|---|---|---|---|---|---|---|")
    for pair, modes in by_pair.items():
        b, s = modes.get("batch"), modes.get("streaming")
        sh = modes.get("sharded")
        if not b or not s:
            print(f"| {pair} | _missing a mode_ | | | | | |")
            continue
        sh_med = fmt_time(sh["median_s"]) if sh else "—"
        sh_speed = (
            f"{b['median_s'] / sh['median_s']:.2f}x" if sh else "—"
        )
        print(
            f"| {pair} | {fmt_time(b['median_s'])} "
            f"| {fmt_time(s['median_s'])} "
            f"| {sh_med} "
            f"| {sh_speed} "
            f"| {b['peak_bytes_per_param']:.3f} "
            f"| {s['peak_bytes_per_param']:.3f} |"
        )
    if not rows:
        print()
        print("_no rows in the bench output_")
    print()
    print(f"{len(rows)} rows · {len(by_pair)} (optimizer, variant) "
          f"pairs × 3 modes")


def render_checkpoint(doc):
    schema = doc.get("schema_version")
    rows = doc.get("rows", [])
    print("## checkpoint v2: serial vs shard-parallel section I/O")
    print()
    print(
        f"schema v{schema:g} · {doc.get('params'):,} params · "
        f"{doc.get('file_bytes'):,} file bytes · "
        f"{doc.get('threads')} threads · "
        f"check={str(doc.get('check')).lower()}"
    )
    print()
    by_op = {}
    for e in rows:
        by_op.setdefault(e["op"], {})[e["mode"]] = e
    print("| op | serial | parallel | speedup |"
          " MB/s serial | MB/s parallel |")
    print("|---|---|---|---|---|---|")
    for op, modes in by_op.items():
        ser, par = modes.get("serial"), modes.get("parallel")
        if not ser or not par:
            print(f"| {op} | _missing a mode_ | | | | |")
            continue
        speed = ser["median_s"] / par["median_s"]
        print(
            f"| {op} | {fmt_time(ser['median_s'])} "
            f"| {fmt_time(par['median_s'])} "
            f"| {speed:.2f}x "
            f"| {ser['mb_per_s']:.0f} "
            f"| {par['mb_per_s']:.0f} |"
        )
    if not rows:
        print()
        print("_no rows in the bench output_")
    print()
    print(f"{len(rows)} rows · {len(by_op)} ops × 2 modes "
          f"(parallel bytes are bit-identical to serial)")
    state_files = doc.get("state_files", [])
    if state_files:
        print()
        print("### on-disk state size by layout (adamw)")
        print()
        print("| optimizer/variant | file bytes | B/param |")
        print("|---|---|---|")
        for e in state_files:
            pair = f"{e['optimizer']}/{e['variant']}"
            print(
                f"| {pair} | {e['file_bytes']:,.0f} "
                f"| {e['bytes_per_param']:.3f} |"
            )


def main():
    if len(sys.argv) != 2:
        sys.exit("usage: bench_summary.py BENCH_<name>.json")
    with open(sys.argv[1], encoding="utf-8") as f:
        doc = json.load(f)

    bench = doc.get("bench")
    if bench == "train_step":
        render_train(doc)
    elif bench == "checkpoint":
        render_checkpoint(doc)
    elif bench == "kernel_hotpath":
        render_kernels(doc)
    else:
        sys.exit(f"unknown bench document: {bench!r}")


if __name__ == "__main__":
    main()
