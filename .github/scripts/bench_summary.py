#!/usr/bin/env python3
"""Render the fused-vs-tiled section of BENCH_kernels.json (schema v3)
as a GitHub job-summary markdown table.

Usage: bench_summary.py BENCH_kernels.json >> "$GITHUB_STEP_SUMMARY"

Keeps zero dependencies (stdlib json only) so the CI step is a single
python3 invocation on the stock runner image.
"""

import json
import sys


def fmt_time(seconds):
    if seconds < 1e-6:
        return f"{seconds * 1e9:.0f} ns"
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f} ms"
    return f"{seconds:.3f} s"


def main():
    if len(sys.argv) != 2:
        sys.exit("usage: bench_summary.py BENCH_kernels.json")
    with open(sys.argv[1], encoding="utf-8") as f:
        doc = json.load(f)

    schema = doc.get("schema_version")
    rows = doc.get("fused", [])
    print("## fused single-pass vs tiled three-pass")
    print()
    print(
        f"schema v{schema:g} · {doc.get('step_elements'):,} params · "
        f"avx2_detected={str(doc.get('avx2_detected')).lower()} · "
        f"check={str(doc.get('check')).lower()}"
    )
    print()
    print("| optimizer/variant | kernels | fused | tiled | speedup |"
          " GB/s fused | GB/s tiled |")
    print("|---|---|---|---|---|---|---|")
    for e in rows:
        pair = f"{e['optimizer']}/{e['variant']}"
        print(
            f"| {pair} | {e['kernels']} "
            f"| {fmt_time(e['fused_median_s'])} "
            f"| {fmt_time(e['tiled_median_s'])} "
            f"| {e['speedup']:.2f}x "
            f"| {e['fused_gb_per_s']:.2f} "
            f"| {e['tiled_gb_per_s']:.2f} |"
        )
    if not rows:
        print()
        print("_no fused rows in the bench output_")

    pairs = {(e["optimizer"], e["variant"]) for e in rows}
    print()
    print(f"{len(rows)} rows · {len(pairs)} distinct (optimizer, "
          f"variant) pairs (universe: 15)")


if __name__ == "__main__":
    main()
