"""AOT pipeline: lower every Layer-1/Layer-2 graph to HLO text artifacts.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the rust `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  <name>.hlo.txt        one per lowered graph
  manifest.json         shapes, dtypes, parameter layouts, hyp layout —
                        everything rust/src/runtime/artifact.rs needs.

Python runs ONCE at `make artifacts`; never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, vision
from .kernels import fused_steps, quant, ref, weight_split

GROUP = configs.GROUP
NHYP = fused_steps.NHYP


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# artifact builders
# ---------------------------------------------------------------------------

def lm_artifacts(cfg: configs.LmConfig):
    p = cfg.param_count
    xspec = spec((cfg.batch, cfg.seq_len), jnp.int32)
    yspec = spec((cfg.batch, cfg.seq_len), jnp.int32)
    yield "fwd_bwd_ref", lambda: lower(
        lambda f, x, y: model.fwd_bwd(f, x, y, cfg),
        spec((p,), jnp.float32), xspec, yspec)
    yield "fwd_bwd_flash", lambda: lower(
        lambda f, x, y: model.fwd_bwd(f, x, y, cfg),
        spec((p,), jnp.bfloat16), xspec, yspec)
    yield "eval_ref", lambda: lower(
        lambda f, x, y: model.evaluate(f, x, y, cfg),
        spec((p,), jnp.float32), xspec, yspec)
    yield "eval_flash", lambda: lower(
        lambda f, x, y: model.evaluate(f, x, y, cfg),
        spec((p,), jnp.bfloat16), xspec, yspec)


def vision_artifacts(cfg: configs.VisionConfig):
    p = cfg.param_count
    xspec = spec((cfg.batch, cfg.input_dim), jnp.float32)
    yspec = spec((cfg.batch,), jnp.int32)
    yield "fwd_bwd_ref", lambda: lower(
        lambda f, x, y: vision.fwd_bwd(f, x, y, cfg),
        spec((p,), jnp.float32), xspec, yspec)
    yield "fwd_bwd_flash", lambda: lower(
        lambda f, x, y: vision.fwd_bwd(f, x, y, cfg),
        spec((p,), jnp.bfloat16), xspec, yspec)
    yield "eval_ref", lambda: lower(
        lambda f, x, y: vision.evaluate(f, x, y, cfg),
        spec((p,), jnp.float32), xspec, yspec)
    yield "eval_flash", lambda: lower(
        lambda f, x, y: vision.evaluate(f, x, y, cfg),
        spec((p,), jnp.bfloat16), xspec, yspec)


def bucket_artifacts(b: int):
    """Optimizer-step graphs over one bucket of b elements.

    PERF (EXPERIMENTS.md §Perf): lowered with block == bucket (grid=1).
    The TPU-shaped default block (8192, VMEM-sized) lowers under
    interpret mode to an unrolled grid of dynamic-slice/update-slice
    copies that XLA CPU executes ~5x slower; one block per bucket is
    the right CPU lowering while the kernels keep their BlockSpec
    structure for the TPU target.
    """
    h = spec((NHYP,), jnp.float32)
    f32, bf16 = spec((b,), jnp.float32), spec((b,), jnp.bfloat16)
    i8, u8 = spec((b,), jnp.int8), spec((b,), jnp.uint8)
    f16s = spec((b // GROUP,), jnp.float16)

    def blk(fn):
        # bind block == bucket size (see docstring)
        def wrapped(*a, _fn=fn):
            return _fn(*a, block=b)
        return wrapped

    yield "opt_adamw_ref", lambda: lower(
        blk(fused_steps.ref_adamw), h, f32, f32, f32, f32)
    yield "opt_sgd_ref", lambda: lower(
        blk(fused_steps.ref_sgd), h, f32, f32, f32)
    yield "opt_lion_ref", lambda: lower(
        blk(fused_steps.ref_lion), h, f32, f32, f32)

    yield "opt_adamw_flash", lambda: lower(
        blk(fused_steps.flash_adamw), h, bf16, i8, i8, f16s, u8, f16s,
        bf16)
    yield "opt_sgd_flash", lambda: lower(
        blk(fused_steps.flash_sgd), h, bf16, i8, i8, f16s, bf16)
    yield "opt_lion_flash", lambda: lower(
        blk(fused_steps.flash_lion), h, bf16, i8, i8, f16s, bf16)

    # Table 4 ablations + Fig. 5 divergence variant
    yield "opt_adamw_wsplit", lambda: lower(
        blk(fused_steps.wsplit_adamw), h, bf16, i8, f32, f32, bf16)
    yield "opt_adamw_quant", lambda: lower(
        blk(fused_steps.quant_adamw), h, f32, i8, f16s, u8, f16s, f32)
    yield "opt_adamw_nocompand", lambda: lower(
        blk(fused_steps.nocompand_adamw), h, bf16, i8, i8, f16s, u8,
        f16s, bf16)


def kernel_artifacts(n_elems: int):
    """Standalone kernel round-trips for Rust<->HLO cross-validation."""
    f32 = spec((n_elems,), jnp.float32)
    bf16 = spec((n_elems,), jnp.bfloat16)
    i8 = spec((n_elems,), jnp.int8)
    i16 = spec((n_elems,), jnp.int16)
    u8 = spec((n_elems,), jnp.uint8)
    f16s = spec((n_elems // GROUP,), jnp.float16)

    yield "split_enc_i8", lambda: lower(
        lambda t: weight_split.split_compress(t, n=ref.N_INT8), f32)
    yield "split_dec_i8", lambda: lower(
        lambda tp, r: weight_split.split_decompress(tp, r, n=ref.N_INT8),
        bf16, i8)
    yield "split_enc_i16", lambda: lower(
        lambda t: weight_split.split_compress(t, n=ref.N_INT16), f32)
    yield "split_dec_i16", lambda: lower(
        lambda tp, r: weight_split.split_decompress(tp, r, n=ref.N_INT16),
        bf16, i16)
    yield "mq_enc", lambda: lower(quant.quant_momentum, f32)
    yield "mq_dec", lambda: lower(quant.dequant_momentum, i8, f16s)
    yield "vq_enc", lambda: lower(quant.quant_variance, f32)
    yield "vq_dec", lambda: lower(quant.dequant_variance, u8, f16s)
    yield "mq_lin_enc", lambda: lower(quant.quant_momentum_linear, f32)
    yield "vq_lin_enc", lambda: lower(quant.quant_variance_linear, f32)


# ---------------------------------------------------------------------------
# manifest
# ---------------------------------------------------------------------------

def layout_json(layout):
    out = []
    off = 0
    for name, shape in layout:
        n = 1
        for s in shape:
            n *= s
        out.append({"name": name, "offset": off, "shape": list(shape)})
        off += n
    return out


def config_digest() -> str:
    src = []
    here = os.path.dirname(__file__)
    for rel in ["configs.py", "model.py", "vision.py", "aot.py",
                "kernels/ref.py", "kernels/weight_split.py",
                "kernels/quant.py", "kernels/fused_steps.py"]:
        with open(os.path.join(here, rel), "rb") as f:
            src.append(f.read())
    return hashlib.sha256(b"".join(src)).hexdigest()[:16]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--presets", default="lm-tiny,vision",
                    help="comma-separated: lm-tiny,lm-small,vision")
    ap.add_argument("--buckets", default=",".join(
        str(b) for b in configs.BUCKET_SIZES))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    digest = config_digest()

    manifest_path = os.path.join(out_dir, "manifest.json")
    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("digest") == digest and \
                old.get("presets") == args.presets and \
                old.get("bucket_arg") == args.buckets:
            print(f"artifacts up to date (digest {digest}); skipping")
            return 0

    manifest = {
        "version": 1,
        "digest": digest,
        "presets": args.presets,
        "bucket_arg": args.buckets,
        "group": GROUP,
        "nhyp": NHYP,
        "hyp_layout": ["lr", "beta1", "beta2", "eps", "wd", "bc1", "bc2",
                       "pad"],
        "n_int8": ref.N_INT8,
        "n_int16": ref.N_INT16,
        "models": {},
        "buckets": {},
        "kernels": {"size": configs.KERNEL_VEC, "artifacts": {}},
    }

    def emit(name: str, builder) -> str:
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        text = builder()
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {fname} ({len(text)//1024} KiB)")
        return fname

    for preset in args.presets.split(","):
        preset = preset.strip()
        if not preset:
            continue
        print(f"[aot] model {preset}")
        if preset in configs.LM_PRESETS:
            cfg = configs.LM_PRESETS[preset]
            arts = {k: emit(f"{preset}.{k}", b)
                    for k, b in lm_artifacts(cfg)}
            manifest["models"][preset] = {
                "kind": "lm", "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
                "seq_len": cfg.seq_len, "batch": cfg.batch,
                "d_ff": cfg.d_ff, "param_count": cfg.param_count,
                "layout": layout_json(cfg.layout()), "artifacts": arts,
            }
        elif preset in configs.VISION_PRESETS:
            cfg = configs.VISION_PRESETS[preset]
            arts = {k: emit(f"{preset}.{k}", b)
                    for k, b in vision_artifacts(cfg)}
            manifest["models"][preset] = {
                "kind": "vision", "input_dim": cfg.input_dim,
                "hidden": list(cfg.hidden), "classes": cfg.classes,
                "batch": cfg.batch, "param_count": cfg.param_count,
                "layout": layout_json(cfg.layout()), "artifacts": arts,
            }
        else:
            print(f"unknown preset {preset!r}", file=sys.stderr)
            return 1

    for b in [int(x) for x in args.buckets.split(",") if x.strip()]:
        print(f"[aot] bucket {b}")
        arts = {k: emit(f"bucket{b}.{k}", fn)
                for k, fn in bucket_artifacts(b)}
        manifest["buckets"][str(b)] = {"size": b, "artifacts": arts}

    print("[aot] kernels")
    manifest["kernels"]["artifacts"] = {
        k: emit(f"kernel.{k}", fn)
        for k, fn in kernel_artifacts(configs.KERNEL_VEC)}

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote manifest.json (digest {digest})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
