"""Layer-2 JAX graphs: MLP image classifier over a flat param buffer.

Stand-in for the paper's ResNet-50/ImageNet track (see DESIGN.md §3
substitution table): it exercises the SGD-with-momentum / AdamW training
paths and an accuracy metric on a synthetic image task generated on the
Rust side.  Same flat-buffer and bf16-activation conventions as model.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import VisionConfig
from .model import unpack


def forward_logits(flat: jnp.ndarray, x: jnp.ndarray, cfg: VisionConfig):
    p = unpack(flat, cfg.layout())
    compute = jnp.bfloat16
    h = x.astype(compute)
    n_layers = len(cfg.hidden) + 1
    for i in range(n_layers):
        h = h @ p[f"fc{i}.w"].astype(compute) + p[f"fc{i}.b"].astype(compute)
        if i < n_layers - 1:
            h = jax.nn.gelu(h)
    return h.astype(jnp.float32)


def loss_fn(flat, x, y, cfg: VisionConfig):
    logits = forward_logits(flat, x, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def fwd_bwd(flat, x, y, cfg: VisionConfig):
    loss, grads = jax.value_and_grad(loss_fn)(flat, x, y, cfg)
    return loss, grads


def evaluate(flat, x, y, cfg: VisionConfig):
    logits = forward_logits(flat, x, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    loss_sum = jnp.sum(logz - gold)
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return loss_sum, ncorrect
