"""FlashTrain build-time compile package (Layer 1 + Layer 2).

Runs only at `make artifacts` time; never imported on the request path.
"""
