"""Layer-1 Pallas kernels for Algorithm 1 (ULP-normalized weight splitting).

The kernels are written for TPU-style tiling (1-D grid over VMEM-resident
blocks, lane-multiple block sizes) but are always lowered with
``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel body to plain HLO ops
that run on any backend (see DESIGN.md §Hardware-Adaptation).

Semantics are defined by ``ref.split_compress`` / ``ref.split_decompress``;
``python/tests/test_weight_split.py`` enforces bit-exact agreement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Block size: multiple of 128 lanes and of the group size; small enough
# that (2+1+4)·BLOCK bytes of VMEM per in-flight block double-buffers
# comfortably inside 16 MiB.
DEFAULT_BLOCK = 4096


def _pick_block(n: int, block: int) -> int:
    block = min(block, n)
    while n % block != 0:
        block //= 2
    return max(block, 1)


def _split_compress_kernel(theta_ref, theta_p_ref, rho_ref, *, n: int,
                           target):
    theta = theta_ref[...]
    theta_p, rho = ref.split_compress(theta, n=n, target=target)
    theta_p_ref[...] = theta_p
    rho_ref[...] = rho


def _split_decompress_kernel(theta_p_ref, rho_ref, out_ref, *, n: int):
    out_ref[...] = ref.split_decompress(theta_p_ref[...], rho_ref[...], n=n)


@functools.partial(jax.jit, static_argnames=("n", "block", "target_name"))
def split_compress(theta: jnp.ndarray, n: int = ref.N_INT8,
                   block: int = DEFAULT_BLOCK, target_name: str = "bfloat16"):
    """Pallas C(theta) -> (theta', rho) over a flat f32 vector."""
    target = jnp.bfloat16 if target_name == "bfloat16" else jnp.float16
    (size,) = theta.shape
    blk = _pick_block(size, block)
    rho_dtype = jnp.int8 if n <= 127 else jnp.int16
    return pl.pallas_call(
        functools.partial(_split_compress_kernel, n=n, target=target),
        grid=(size // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((size,), target),
            jax.ShapeDtypeStruct((size,), rho_dtype),
        ],
        interpret=True,
    )(theta)


@functools.partial(jax.jit, static_argnames=("n", "block"))
def split_decompress(theta_p: jnp.ndarray, rho: jnp.ndarray,
                     n: int = ref.N_INT8, block: int = DEFAULT_BLOCK):
    """Pallas C^-1(theta', rho) -> theta_hat."""
    (size,) = theta_p.shape
    blk = _pick_block(size, block)
    return pl.pallas_call(
        functools.partial(_split_decompress_kernel, n=n),
        grid=(size // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((size,), jnp.float32),
        interpret=True,
    )(theta_p, rho)
