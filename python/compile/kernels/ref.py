"""Pure-jnp reference oracle for every FlashOptim kernel.

This module is the *semantic definition* of the paper's algorithms:

  * Algorithm 1 — ULP-normalized weight splitting  C / C^-1
  * Algorithm 2 — companded momentum quantization  Q_m / Q_m^-1
  * Algorithm 3 — companded variance quantization  Q_v / Q_v^-1
  * Algorithms 4/5/6 — Flash{AdamW,SGD,Lion} fused update steps

The Pallas kernels in `weight_split.py`, `quant.py` and `fused_steps.py`
are validated against these functions by `python/tests/`, and the Rust
`formats` module mirrors them bit-for-bit (cross-validated through the
HLO runtime in `rust/tests/`).

Everything here is plain jax.numpy — no pallas — so it can run anywhere
and serves as the correctness signal.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Group size for group-wise quantization (paper §3.2, G = 32).
GROUP = 32

# N constants from Algorithm 1.
N_INT8 = 127
N_INT16 = 32767


# ---------------------------------------------------------------------------
# exact power-of-two helpers
# ---------------------------------------------------------------------------

def pow2_i32(k: jnp.ndarray) -> jnp.ndarray:
    """Exact 2**k as float32 for integer k in [-149, 127].

    Built by bit-twiddling so the result is exact even in the subnormal
    range.
    """
    k = jnp.asarray(k, jnp.int32)
    # normal: biased exponent k+127 in [1, 254]
    normal_bits = ((k + 127) << 23).astype(jnp.uint32)
    # subnormal: 2^k has the mantissa bit at position k+149
    sub_shift = jnp.clip(k + 149, 0, 22).astype(jnp.uint32)
    sub_bits = jnp.uint32(1) << sub_shift
    bits = jnp.where(k >= -126, normal_bits, sub_bits)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def ulp_exponent_bf16(theta_p: jnp.ndarray) -> jnp.ndarray:
    """Integer e such that ULP(theta_p) = 2**e, for bfloat16 theta_p.

    BF16 has 7 explicit mantissa bits.  For a normal value with biased
    exponent E the ULP is 2^(E-127-7); zeros and subnormals share the
    ULP of the smallest normal binade, 2^(-126-7).
    """
    bits = jax.lax.bitcast_convert_type(theta_p, jnp.uint16).astype(jnp.int32)
    exp = (bits >> 7) & 0xFF
    return jnp.where(exp > 0, exp - 127 - 7, -126 - 7)


def ulp_exponent_f16(theta_p: jnp.ndarray) -> jnp.ndarray:
    """Same as above for IEEE float16 (10 explicit mantissa bits)."""
    bits = jax.lax.bitcast_convert_type(theta_p, jnp.uint16).astype(jnp.int32)
    exp = (bits >> 10) & 0x1F
    return jnp.where(exp > 0, exp - 15 - 10, -14 - 10)


def _ulp_exponent(theta_p: jnp.ndarray) -> jnp.ndarray:
    if theta_p.dtype == jnp.bfloat16:
        return ulp_exponent_bf16(theta_p)
    if theta_p.dtype == jnp.float16:
        return ulp_exponent_f16(theta_p)
    raise ValueError(f"unsupported split target dtype {theta_p.dtype}")


# ---------------------------------------------------------------------------
# Algorithm 1 — weight splitting
# ---------------------------------------------------------------------------

def split_compress(theta: jnp.ndarray, n: int = N_INT8,
                   target=jnp.bfloat16):
    """C(theta) -> (theta', rho).  Algorithm 1 lines 1-8.

    theta  : float32 tensor
    n      : 127 for INT8 correction, 32767 for INT16
    target : low-precision weight dtype (bfloat16 or float16)
    """
    theta = theta.astype(jnp.float32)
    theta_p = theta.astype(target)                    # Downcast (RNE)
    e = theta - theta_p.astype(jnp.float32)           # exact (Sterbenz)
    ell = _ulp_exponent(theta_p) - 1                  # 2^ell = ULP/2
    h = -(ell) // 2                                   # floor(-ell/2)
    # e_norm = e * 2^-ell, two exact scaling steps for range safety
    e_norm = (e * pow2_i32(h)) * pow2_i32(-ell - h)
    e_norm = jnp.clip(e_norm, -1.0, 1.0)
    rho_f = jnp.round(e_norm * n)
    dtype = jnp.int8 if n <= 127 else jnp.int16
    rho = jnp.clip(rho_f, -n, n).astype(dtype)
    return theta_p, rho


def split_decompress(theta_p: jnp.ndarray, rho: jnp.ndarray,
                     n: int = N_INT8) -> jnp.ndarray:
    """C^-1(theta', rho) -> theta_hat.  Algorithm 1 lines 9-13."""
    ell = _ulp_exponent(theta_p) - 1
    h = ell // 2                                      # floor(ell/2)
    e = ((rho.astype(jnp.float32) / n) * pow2_i32(h)) * pow2_i32(ell - h)
    return theta_p.astype(jnp.float32) + e


# ---------------------------------------------------------------------------
# Algorithm 2 — momentum quantization (softsign companding)
# ---------------------------------------------------------------------------

def _group(x: jnp.ndarray) -> jnp.ndarray:
    assert x.size % GROUP == 0, f"size {x.size} not divisible by {GROUP}"
    return x.reshape(-1, GROUP)


def phi_m(x: jnp.ndarray) -> jnp.ndarray:
    """Momentum companding function, eq. (3)."""
    return 2.0 * x / (1.0 + jnp.abs(x))


def phi_m_inv(z: jnp.ndarray) -> jnp.ndarray:
    return z / (2.0 - jnp.abs(z))


def quant_momentum(m: jnp.ndarray):
    """Q_m(m) -> (q: int8, s: float16).  Algorithm 2."""
    shape = m.shape
    g = _group(m.astype(jnp.float32))
    s = jnp.max(jnp.abs(g), axis=1)                   # absmax scale
    s = jnp.minimum(s, 65504.0)                       # saturate to f16 max
    s16 = s.astype(jnp.float16)
    s_safe = jnp.where(s16 > 0, s16.astype(jnp.float32), 1.0)
    mpp = phi_m(g / s_safe[:, None])
    q = jnp.clip(jnp.round(mpp * 127.0), -127, 127).astype(jnp.int8)
    return q.reshape(shape), s16


def dequant_momentum(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    """Q_m^-1(q, s) -> m.  Algorithm 2 lines 8-13."""
    shape = q.shape
    g = _group(q).astype(jnp.float32) / 127.0
    mp = phi_m_inv(g)
    return (mp * s.astype(jnp.float32)[:, None]).reshape(shape)


def quant_momentum_linear(m: jnp.ndarray):
    """Ablation: group-wise linear (no companding) int8 quantization."""
    shape = m.shape
    g = _group(m.astype(jnp.float32))
    s = jnp.max(jnp.abs(g), axis=1)
    s = jnp.minimum(s, 65504.0)                       # saturate to f16 max
    s16 = s.astype(jnp.float16)
    s_safe = jnp.where(s16 > 0, s16.astype(jnp.float32), 1.0)
    q = jnp.clip(jnp.round(g / s_safe[:, None] * 127.0), -127, 127)
    return q.astype(jnp.int8).reshape(shape), s16


def dequant_momentum_linear(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    shape = q.shape
    g = _group(q).astype(jnp.float32) / 127.0
    return (g * s.astype(jnp.float32)[:, None]).reshape(shape)


# ---------------------------------------------------------------------------
# Algorithm 3 — variance quantization (sqrt companding)
# ---------------------------------------------------------------------------

def quant_variance(v: jnp.ndarray):
    """Q_v(v) -> (q: uint8, s: float16).  Algorithm 3."""
    shape = v.shape
    vp = jnp.sqrt(_group(v.astype(jnp.float32)))
    s = jnp.max(vp, axis=1)
    s = jnp.minimum(s, 65504.0)                       # saturate to f16 max
    s16 = s.astype(jnp.float16)
    s_safe = jnp.where(s16 > 0, s16.astype(jnp.float32), 1.0)
    q = jnp.clip(jnp.round(vp / s_safe[:, None] * 255.0), 0, 255)
    return q.astype(jnp.uint8).reshape(shape), s16


def dequant_variance(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    shape = q.shape
    vp = _group(q).astype(jnp.float32) / 255.0 * s.astype(jnp.float32)[:, None]
    return (vp * vp).reshape(shape)


def quant_variance_linear(v: jnp.ndarray):
    """Ablation: linear uint8 quantization of raw variance (Fig. 5)."""
    shape = v.shape
    g = _group(v.astype(jnp.float32))
    s = jnp.max(g, axis=1)
    s = jnp.minimum(s, 65504.0)                       # saturate to f16 max
    s16 = s.astype(jnp.float16)
    s_safe = jnp.where(s16 > 0, s16.astype(jnp.float32), 1.0)
    q = jnp.clip(jnp.round(g / s_safe[:, None] * 255.0), 0, 255)
    return q.astype(jnp.uint8).reshape(shape), s16


def dequant_variance_linear(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    shape = q.shape
    g = _group(q).astype(jnp.float32) / 255.0
    return (g * s.astype(jnp.float32)[:, None]).reshape(shape)


# ---------------------------------------------------------------------------
# Reference (FP32) optimizer update rules
# ---------------------------------------------------------------------------

def adamw_ref(theta, m, v, g, lr, beta1, beta2, eps, wd, bc1, bc2):
    """One fp32 AdamW step.  bc1 = 1/(1-beta1^t), bc2 = 1/(1-beta2^t)."""
    g = g.astype(jnp.float32)
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    m_hat = m * bc1
    v_hat = v * bc2
    theta = theta - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * theta)
    return theta, m, v


def sgd_ref(theta, m, g, lr, mu, wd):
    """One fp32 SGD-with-momentum step (Algorithm 5 semantics)."""
    g = g.astype(jnp.float32)
    m = mu * m + g
    theta = theta - lr * (m + wd * theta)
    return theta, m


def lion_ref(theta, m, g, lr, beta1, beta2, wd):
    """One fp32 Lion step (Algorithm 6 semantics)."""
    g = g.astype(jnp.float32)
    u = jnp.sign(beta1 * m + (1.0 - beta1) * g)
    m = beta2 * m + (1.0 - beta2) * g
    theta = theta - lr * (u + wd * theta)
    return theta, m


# ---------------------------------------------------------------------------
# Flash optimizer steps, composed from the reference pieces.
# These define the exact semantics the fused Pallas kernels must match.
# ---------------------------------------------------------------------------

def flash_adamw_ref(theta_p, rho, mq, ms, vq, vs, g,
                    lr, beta1, beta2, eps, wd, bc1, bc2, n=N_INT8):
    """Algorithm 4 lines 9-22: prologue + AdamW update + epilogue."""
    m = dequant_momentum(mq, ms)
    v = dequant_variance(vq, vs)
    theta = split_decompress(theta_p, rho, n)
    theta, m, v = adamw_ref(theta, m, v, g, lr, beta1, beta2, eps, wd,
                            bc1, bc2)
    mq, ms = quant_momentum(m)
    vq, vs = quant_variance(v)
    theta_p, rho = split_compress(theta, n)
    return theta_p, rho, mq, ms, vq, vs


def flash_sgd_ref(theta_p, rho, mq, ms, g, lr, mu, wd, n=N_INT8):
    """Algorithm 5."""
    m = dequant_momentum(mq, ms)
    theta = split_decompress(theta_p, rho, n)
    theta, m = sgd_ref(theta, m, g, lr, mu, wd)
    mq, ms = quant_momentum(m)
    theta_p, rho = split_compress(theta, n)
    return theta_p, rho, mq, ms


def flash_lion_ref(theta_p, rho, mq, ms, g, lr, beta1, beta2, wd, n=N_INT8):
    """Algorithm 6."""
    m = dequant_momentum(mq, ms)
    theta = split_decompress(theta_p, rho, n)
    theta, m = lion_ref(theta, m, g, lr, beta1, beta2, wd)
    mq, ms = quant_momentum(m)
    theta_p, rho = split_compress(theta, n)
    return theta_p, rho, mq, ms


# Ablation variants used by Table 4 / Figure 5 -------------------------------

def wsplit_adamw_ref(theta_p, rho, m, v, g,
                     lr, beta1, beta2, eps, wd, bc1, bc2, n=N_INT8):
    """Weight splitting only; fp32 optimizer states."""
    theta = split_decompress(theta_p, rho, n)
    theta, m, v = adamw_ref(theta, m, v, g, lr, beta1, beta2, eps, wd,
                            bc1, bc2)
    theta_p, rho = split_compress(theta, n)
    return theta_p, rho, m, v


def quant_adamw_ref(theta, mq, ms, vq, vs, g,
                    lr, beta1, beta2, eps, wd, bc1, bc2):
    """State quantization only; fp32 master weights."""
    m = dequant_momentum(mq, ms)
    v = dequant_variance(vq, vs)
    theta, m, v = adamw_ref(theta, m, v, g, lr, beta1, beta2, eps, wd,
                            bc1, bc2)
    mq, ms = quant_momentum(m)
    vq, vs = quant_variance(v)
    return theta, mq, ms, vq, vs


def nocompand_adamw_ref(theta_p, rho, mq, ms, vq, vs, g,
                        lr, beta1, beta2, eps, wd, bc1, bc2, n=N_INT8):
    """Fig. 5 ablation: linear (no companding) 8-bit state quantization."""
    m = dequant_momentum_linear(mq, ms)
    v = dequant_variance_linear(vq, vs)
    theta = split_decompress(theta_p, rho, n)
    theta, m, v = adamw_ref(theta, m, v, g, lr, beta1, beta2, eps, wd,
                            bc1, bc2)
    mq, ms = quant_momentum_linear(m)
    vq, vs = quant_variance_linear(v)
    theta_p, rho = split_compress(theta, n)
    return theta_p, rho, mq, ms, vq, vs
