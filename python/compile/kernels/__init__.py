"""FlashOptim Layer-1 kernels (Pallas, interpret mode) and their oracle."""

from . import fused_steps, quant, ref, weight_split  # noqa: F401
