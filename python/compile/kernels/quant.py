"""Layer-1 Pallas kernels for Algorithms 2 & 3 (companded state quantization).

Group-wise (G=32) absmax quantization with companding:
  * momentum: softsign companding -> int8 + f16 group scales
  * variance: sqrt companding    -> uint8 + f16 group scales
plus the linear (no-companding) ablation variants used by Figure 5.

interpret=True everywhere; see weight_split.py for why.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 4096
GROUP = ref.GROUP


def _pick_block(n: int, block: int) -> int:
    block = min(block, n)
    while n % block != 0 or block % GROUP != 0:
        block //= 2
        if block < GROUP:
            raise ValueError(f"size {n} not tileable by group {GROUP}")
    return block


def _make_enc_kernel(fn):
    def kernel(x_ref, q_ref, s_ref):
        q, s = fn(x_ref[...])
        q_ref[...] = q
        s_ref[...] = s
    return kernel


def _make_dec_kernel(fn):
    def kernel(q_ref, s_ref, out_ref):
        out_ref[...] = fn(q_ref[...], s_ref[...])
    return kernel


def _enc(fn, q_dtype):
    @functools.partial(jax.jit, static_argnames=("block",))
    def run(x, block: int = DEFAULT_BLOCK):
        (size,) = x.shape
        blk = _pick_block(size, block)
        return pl.pallas_call(
            _make_enc_kernel(fn),
            grid=(size // blk,),
            in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
            out_specs=[
                pl.BlockSpec((blk,), lambda i: (i,)),
                pl.BlockSpec((blk // GROUP,), lambda i: (i,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((size,), q_dtype),
                jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
            ],
            interpret=True,
        )(x)
    return run


def _dec(fn):
    @functools.partial(jax.jit, static_argnames=("block",))
    def run(q, s, block: int = DEFAULT_BLOCK):
        (size,) = q.shape
        blk = _pick_block(size, block)
        return pl.pallas_call(
            _make_dec_kernel(fn),
            grid=(size // blk,),
            in_specs=[
                pl.BlockSpec((blk,), lambda i: (i,)),
                pl.BlockSpec((blk // GROUP,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((size,), jnp.float32),
            interpret=True,
        )(q, s)
    return run


# Public kernel entry points -------------------------------------------------

quant_momentum = _enc(ref.quant_momentum, jnp.int8)
dequant_momentum = _dec(ref.dequant_momentum)
quant_momentum_linear = _enc(ref.quant_momentum_linear, jnp.int8)
dequant_momentum_linear = _dec(ref.dequant_momentum_linear)

quant_variance = _enc(ref.quant_variance, jnp.uint8)
dequant_variance = _dec(ref.dequant_variance)
quant_variance_linear = _enc(ref.quant_variance_linear, jnp.uint8)
dequant_variance_linear = _dec(ref.dequant_variance_linear)
