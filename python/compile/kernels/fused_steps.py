"""Layer-1 fused optimizer-step Pallas kernels (Algorithms 4/5/6).

One kernel invocation performs, per VMEM-resident block, the full
  dequantize -> reconstruct master weight -> optimizer update
  -> requantize -> re-split
sequence, so each optimizer-state byte moves HBM<->VMEM exactly once per
step.  This is the TPU mapping of the paper's single fused Triton kernel
(§3.4); on GPU the paper tiles with a 1-D threadblock grid, here the 1-D
Pallas grid + BlockSpec plays that role (DESIGN.md §Hardware-Adaptation).

Hyperparameters arrive as a small f32 vector so the same compiled
artifact serves any learning-rate schedule / betas without re-lowering.
Layout of the `hyp` vector (fixed, mirrored by rust/src/optim):

  idx  0    1      2      3    4   5    6
       lr   beta1  beta2  eps  wd  bc1  bc2      (adamw)
       lr   mu     -      -    wd  -    -        (sgd)
       lr   beta1  beta2  -    wd  -    -        (lion)

All kernels operate on one flat "bucket" of parameters; padding elements
(zero theta / zero grad) are fixed points of every update rule, so padded
tails stay exactly zero.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 8192
GROUP = ref.GROUP
NHYP = 8


def _pick_block(n: int, block: int) -> int:
    block = min(block, n)
    while n % block != 0 or block % GROUP != 0:
        block //= 2
        if block < GROUP:
            raise ValueError(f"bucket {n} not tileable by group {GROUP}")
    return block


def _hyp_spec():
    return pl.BlockSpec((NHYP,), lambda i: (0,))


def _vec(blk):
    return pl.BlockSpec((blk,), lambda i: (i,))


def _scale(blk):
    return pl.BlockSpec((blk // GROUP,), lambda i: (i,))


# ---------------------------------------------------------------------------
# FlashAdamW (Algorithm 4)
# ---------------------------------------------------------------------------

def _flash_adamw_kernel(hyp_ref, tp_ref, rho_ref, mq_ref, ms_ref, vq_ref,
                        vs_ref, g_ref,
                        tp_o, rho_o, mq_o, ms_o, vq_o, vs_o, *, n):
    hyp = hyp_ref[...]
    lr, b1, b2, eps, wd, bc1, bc2 = (hyp[0], hyp[1], hyp[2], hyp[3],
                                     hyp[4], hyp[5], hyp[6])
    out = ref.flash_adamw_ref(tp_ref[...], rho_ref[...], mq_ref[...],
                              ms_ref[...], vq_ref[...], vs_ref[...],
                              g_ref[...], lr, b1, b2, eps, wd, bc1, bc2,
                              n=n)
    tp_o[...], rho_o[...], mq_o[...], ms_o[...], vq_o[...], vs_o[...] = out


@functools.partial(jax.jit, static_argnames=("n", "block"))
def flash_adamw(hyp, theta_p, rho, mq, ms, vq, vs, g,
                n: int = ref.N_INT8, block: int = DEFAULT_BLOCK):
    (size,) = theta_p.shape
    blk = _pick_block(size, block)
    rho_dtype = jnp.int8 if n <= 127 else jnp.int16
    return pl.pallas_call(
        functools.partial(_flash_adamw_kernel, n=n),
        grid=(size // blk,),
        in_specs=[_hyp_spec(), _vec(blk), _vec(blk), _vec(blk), _scale(blk),
                  _vec(blk), _scale(blk), _vec(blk)],
        out_specs=[_vec(blk), _vec(blk), _vec(blk), _scale(blk), _vec(blk),
                   _scale(blk)],
        out_shape=[
            jax.ShapeDtypeStruct((size,), jnp.bfloat16),
            jax.ShapeDtypeStruct((size,), rho_dtype),
            jax.ShapeDtypeStruct((size,), jnp.int8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
            jax.ShapeDtypeStruct((size,), jnp.uint8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
        ],
        interpret=True,
    )(hyp, theta_p, rho, mq, ms, vq, vs, g)


# ---------------------------------------------------------------------------
# FlashSGD (Algorithm 5)
# ---------------------------------------------------------------------------

def _flash_sgd_kernel(hyp_ref, tp_ref, rho_ref, mq_ref, ms_ref, g_ref,
                      tp_o, rho_o, mq_o, ms_o, *, n):
    hyp = hyp_ref[...]
    lr, mu, wd = hyp[0], hyp[1], hyp[4]
    out = ref.flash_sgd_ref(tp_ref[...], rho_ref[...], mq_ref[...],
                            ms_ref[...], g_ref[...], lr, mu, wd, n=n)
    tp_o[...], rho_o[...], mq_o[...], ms_o[...] = out


@functools.partial(jax.jit, static_argnames=("n", "block"))
def flash_sgd(hyp, theta_p, rho, mq, ms, g,
              n: int = ref.N_INT8, block: int = DEFAULT_BLOCK):
    (size,) = theta_p.shape
    blk = _pick_block(size, block)
    rho_dtype = jnp.int8 if n <= 127 else jnp.int16
    return pl.pallas_call(
        functools.partial(_flash_sgd_kernel, n=n),
        grid=(size // blk,),
        in_specs=[_hyp_spec(), _vec(blk), _vec(blk), _vec(blk), _scale(blk),
                  _vec(blk)],
        out_specs=[_vec(blk), _vec(blk), _vec(blk), _scale(blk)],
        out_shape=[
            jax.ShapeDtypeStruct((size,), jnp.bfloat16),
            jax.ShapeDtypeStruct((size,), rho_dtype),
            jax.ShapeDtypeStruct((size,), jnp.int8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
        ],
        interpret=True,
    )(hyp, theta_p, rho, mq, ms, g)


# ---------------------------------------------------------------------------
# FlashLion (Algorithm 6)
# ---------------------------------------------------------------------------

def _flash_lion_kernel(hyp_ref, tp_ref, rho_ref, mq_ref, ms_ref, g_ref,
                       tp_o, rho_o, mq_o, ms_o, *, n):
    hyp = hyp_ref[...]
    lr, b1, b2, wd = hyp[0], hyp[1], hyp[2], hyp[4]
    out = ref.flash_lion_ref(tp_ref[...], rho_ref[...], mq_ref[...],
                             ms_ref[...], g_ref[...], lr, b1, b2, wd, n=n)
    tp_o[...], rho_o[...], mq_o[...], ms_o[...] = out


@functools.partial(jax.jit, static_argnames=("n", "block"))
def flash_lion(hyp, theta_p, rho, mq, ms, g,
               n: int = ref.N_INT8, block: int = DEFAULT_BLOCK):
    (size,) = theta_p.shape
    blk = _pick_block(size, block)
    rho_dtype = jnp.int8 if n <= 127 else jnp.int16
    return pl.pallas_call(
        functools.partial(_flash_lion_kernel, n=n),
        grid=(size // blk,),
        in_specs=[_hyp_spec(), _vec(blk), _vec(blk), _vec(blk), _scale(blk),
                  _vec(blk)],
        out_specs=[_vec(blk), _vec(blk), _vec(blk), _scale(blk)],
        out_shape=[
            jax.ShapeDtypeStruct((size,), jnp.bfloat16),
            jax.ShapeDtypeStruct((size,), rho_dtype),
            jax.ShapeDtypeStruct((size,), jnp.int8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
        ],
        interpret=True,
    )(hyp, theta_p, rho, mq, ms, g)


# ---------------------------------------------------------------------------
# Reference fp32 steps (lowered with the same bucket/tiling structure so the
# step-time comparison in Table 4 is apples-to-apples)
# ---------------------------------------------------------------------------

def _ref_adamw_kernel(hyp_ref, t_ref, m_ref, v_ref, g_ref, t_o, m_o, v_o):
    hyp = hyp_ref[...]
    out = ref.adamw_ref(t_ref[...], m_ref[...], v_ref[...], g_ref[...],
                        hyp[0], hyp[1], hyp[2], hyp[3], hyp[4], hyp[5],
                        hyp[6])
    t_o[...], m_o[...], v_o[...] = out


@functools.partial(jax.jit, static_argnames=("block",))
def ref_adamw(hyp, theta, m, v, g, block: int = DEFAULT_BLOCK):
    (size,) = theta.shape
    blk = _pick_block(size, block)
    return pl.pallas_call(
        _ref_adamw_kernel,
        grid=(size // blk,),
        in_specs=[_hyp_spec()] + [_vec(blk)] * 4,
        out_specs=[_vec(blk)] * 3,
        out_shape=[jax.ShapeDtypeStruct((size,), jnp.float32)] * 3,
        interpret=True,
    )(hyp, theta, m, v, g)


def _ref_sgd_kernel(hyp_ref, t_ref, m_ref, g_ref, t_o, m_o):
    hyp = hyp_ref[...]
    t_o[...], m_o[...] = ref.sgd_ref(t_ref[...], m_ref[...], g_ref[...],
                                     hyp[0], hyp[1], hyp[4])


@functools.partial(jax.jit, static_argnames=("block",))
def ref_sgd(hyp, theta, m, g, block: int = DEFAULT_BLOCK):
    (size,) = theta.shape
    blk = _pick_block(size, block)
    return pl.pallas_call(
        _ref_sgd_kernel,
        grid=(size // blk,),
        in_specs=[_hyp_spec()] + [_vec(blk)] * 3,
        out_specs=[_vec(blk)] * 2,
        out_shape=[jax.ShapeDtypeStruct((size,), jnp.float32)] * 2,
        interpret=True,
    )(hyp, theta, m, g)


def _ref_lion_kernel(hyp_ref, t_ref, m_ref, g_ref, t_o, m_o):
    hyp = hyp_ref[...]
    t_o[...], m_o[...] = ref.lion_ref(t_ref[...], m_ref[...], g_ref[...],
                                      hyp[0], hyp[1], hyp[2], hyp[4])


@functools.partial(jax.jit, static_argnames=("block",))
def ref_lion(hyp, theta, m, g, block: int = DEFAULT_BLOCK):
    (size,) = theta.shape
    blk = _pick_block(size, block)
    return pl.pallas_call(
        _ref_lion_kernel,
        grid=(size // blk,),
        in_specs=[_hyp_spec()] + [_vec(blk)] * 3,
        out_specs=[_vec(blk)] * 2,
        out_shape=[jax.ShapeDtypeStruct((size,), jnp.float32)] * 2,
        interpret=True,
    )(hyp, theta, m, g)


# ---------------------------------------------------------------------------
# Ablation steps (Table 4: Weight Split only / Opt. Quant. only;
# Figure 5: no-companding)
# ---------------------------------------------------------------------------

def _wsplit_adamw_kernel(hyp_ref, tp_ref, rho_ref, m_ref, v_ref, g_ref,
                         tp_o, rho_o, m_o, v_o, *, n):
    hyp = hyp_ref[...]
    out = ref.wsplit_adamw_ref(tp_ref[...], rho_ref[...], m_ref[...],
                               v_ref[...], g_ref[...], hyp[0], hyp[1],
                               hyp[2], hyp[3], hyp[4], hyp[5], hyp[6], n=n)
    tp_o[...], rho_o[...], m_o[...], v_o[...] = out


@functools.partial(jax.jit, static_argnames=("n", "block"))
def wsplit_adamw(hyp, theta_p, rho, m, v, g,
                 n: int = ref.N_INT8, block: int = DEFAULT_BLOCK):
    (size,) = theta_p.shape
    blk = _pick_block(size, block)
    rho_dtype = jnp.int8 if n <= 127 else jnp.int16
    return pl.pallas_call(
        functools.partial(_wsplit_adamw_kernel, n=n),
        grid=(size // blk,),
        in_specs=[_hyp_spec()] + [_vec(blk)] * 5,
        out_specs=[_vec(blk)] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((size,), jnp.bfloat16),
            jax.ShapeDtypeStruct((size,), rho_dtype),
            jax.ShapeDtypeStruct((size,), jnp.float32),
            jax.ShapeDtypeStruct((size,), jnp.float32),
        ],
        interpret=True,
    )(hyp, theta_p, rho, m, v, g)


def _quant_adamw_kernel(hyp_ref, t_ref, mq_ref, ms_ref, vq_ref, vs_ref,
                        g_ref, t_o, mq_o, ms_o, vq_o, vs_o):
    hyp = hyp_ref[...]
    out = ref.quant_adamw_ref(t_ref[...], mq_ref[...], ms_ref[...],
                              vq_ref[...], vs_ref[...], g_ref[...],
                              hyp[0], hyp[1], hyp[2], hyp[3], hyp[4],
                              hyp[5], hyp[6])
    t_o[...], mq_o[...], ms_o[...], vq_o[...], vs_o[...] = out


@functools.partial(jax.jit, static_argnames=("block",))
def quant_adamw(hyp, theta, mq, ms, vq, vs, g, block: int = DEFAULT_BLOCK):
    (size,) = theta.shape
    blk = _pick_block(size, block)
    return pl.pallas_call(
        _quant_adamw_kernel,
        grid=(size // blk,),
        in_specs=[_hyp_spec(), _vec(blk), _vec(blk), _scale(blk), _vec(blk),
                  _scale(blk), _vec(blk)],
        out_specs=[_vec(blk), _vec(blk), _scale(blk), _vec(blk),
                   _scale(blk)],
        out_shape=[
            jax.ShapeDtypeStruct((size,), jnp.float32),
            jax.ShapeDtypeStruct((size,), jnp.int8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
            jax.ShapeDtypeStruct((size,), jnp.uint8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
        ],
        interpret=True,
    )(hyp, theta, mq, ms, vq, vs, g)


def _nocompand_adamw_kernel(hyp_ref, tp_ref, rho_ref, mq_ref, ms_ref,
                            vq_ref, vs_ref, g_ref,
                            tp_o, rho_o, mq_o, ms_o, vq_o, vs_o, *, n):
    hyp = hyp_ref[...]
    out = ref.nocompand_adamw_ref(tp_ref[...], rho_ref[...], mq_ref[...],
                                  ms_ref[...], vq_ref[...], vs_ref[...],
                                  g_ref[...], hyp[0], hyp[1], hyp[2],
                                  hyp[3], hyp[4], hyp[5], hyp[6], n=n)
    tp_o[...], rho_o[...], mq_o[...], ms_o[...], vq_o[...], vs_o[...] = out


@functools.partial(jax.jit, static_argnames=("n", "block"))
def nocompand_adamw(hyp, theta_p, rho, mq, ms, vq, vs, g,
                    n: int = ref.N_INT8, block: int = DEFAULT_BLOCK):
    (size,) = theta_p.shape
    blk = _pick_block(size, block)
    rho_dtype = jnp.int8 if n <= 127 else jnp.int16
    return pl.pallas_call(
        functools.partial(_nocompand_adamw_kernel, n=n),
        grid=(size // blk,),
        in_specs=[_hyp_spec(), _vec(blk), _vec(blk), _vec(blk), _scale(blk),
                  _vec(blk), _scale(blk), _vec(blk)],
        out_specs=[_vec(blk), _vec(blk), _vec(blk), _scale(blk), _vec(blk),
                   _scale(blk)],
        out_shape=[
            jax.ShapeDtypeStruct((size,), jnp.bfloat16),
            jax.ShapeDtypeStruct((size,), rho_dtype),
            jax.ShapeDtypeStruct((size,), jnp.int8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
            jax.ShapeDtypeStruct((size,), jnp.uint8),
            jax.ShapeDtypeStruct((size // GROUP,), jnp.float16),
        ],
        interpret=True,
    )(hyp, theta_p, rho, mq, ms, vq, vs, g)
