"""Layer-2 JAX graphs: decoder-only transformer LM over a flat param buffer.

Both training tracks compute activations in bfloat16 (paper §4.1 table):
  * reference track: params f32 (master), downcast to bf16 inside fwd;
    gradients come back f32.
  * flash track: params *are* bf16 (theta'); training runs directly on the
    low-precision weights (Algorithm 4 line 8); gradients come back bf16.

The flat-buffer convention (DESIGN.md §1) keeps HLO signatures small and
lets the Rust coordinator treat parameters/optimizer state as opaque
buckets, which is what enables gradient release.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .configs import LmConfig


def unpack(flat: jnp.ndarray, layout: List[Tuple[str, Tuple[int, ...]]]):
    """Slice the flat buffer into named views (no copies after fusion)."""
    params = {}
    off = 0
    for name, shape in layout:
        n = 1
        for s in shape:
            n *= s
        params[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        off += n
    return params


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-5)
    return ((xf / rms) * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def attention(x: jnp.ndarray, wqkv: jnp.ndarray, wo: jnp.ndarray,
              n_heads: int) -> jnp.ndarray:
    b, t, d = x.shape
    hd = d // n_heads
    qkv = x @ wqkv                                # [b, t, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(z):
        return z.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)).astype(jnp.float32)
    att = att / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1).astype(x.dtype)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def forward_logits(flat: jnp.ndarray, x: jnp.ndarray, cfg: LmConfig):
    """Token logits [b, t, vocab] in f32.  flat may be f32 or bf16."""
    p = unpack(flat, cfg.layout())
    compute = jnp.bfloat16
    wte = p["wte"].astype(compute)
    h = wte[x] + p["wpe"].astype(compute)[None, : x.shape[1]]
    for i in range(cfg.n_layers):
        h = h + attention(rms_norm(h, p[f"h{i}.ln1"]),
                          p[f"h{i}.wqkv"].astype(compute),
                          p[f"h{i}.wo"].astype(compute), cfg.n_heads)
        z = rms_norm(h, p[f"h{i}.ln2"])
        z = jax.nn.gelu(z @ p[f"h{i}.w1"].astype(compute))
        h = h + z @ p[f"h{i}.w2"].astype(compute)
    h = rms_norm(h, p["lnf"])
    logits = h @ wte.T                            # tied head
    return logits.astype(jnp.float32)


def loss_fn(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
            cfg: LmConfig) -> jnp.ndarray:
    """Mean next-token cross-entropy (f32)."""
    logits = forward_logits(flat, x, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def fwd_bwd(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
            cfg: LmConfig):
    """(loss, grads) — grads share the dtype of `flat`."""
    loss, grads = jax.value_and_grad(loss_fn)(flat, x, y, cfg)
    return loss, grads


def evaluate(flat: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
             cfg: LmConfig):
    """(loss_sum f32, ncorrect i32) over all next-token positions."""
    logits = forward_logits(flat, x, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    loss_sum = jnp.sum(logz - gold)
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return loss_sum, ncorrect
