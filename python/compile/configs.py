"""Model / bucket configuration presets shared by the AOT pipeline.

The Rust side never imports this; it reads the same information from
artifacts/manifest.json written by aot.py.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

GROUP = 32


@dataclasses.dataclass(frozen=True)
class LmConfig:
    """Decoder-only transformer LM (GPT-2-style, RMSNorm, tied head)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int
    ff_mult: int = 4

    @property
    def d_ff(self) -> int:
        return self.ff_mult * self.d_model

    def layout(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) parameter layout of the flat buffer."""
        d, f = self.d_model, self.d_ff
        out: List[Tuple[str, Tuple[int, ...]]] = [
            ("wte", (self.vocab, d)),
            ("wpe", (self.seq_len, d)),
        ]
        for i in range(self.n_layers):
            out += [
                (f"h{i}.ln1", (d,)),
                (f"h{i}.wqkv", (d, 3 * d)),
                (f"h{i}.wo", (d, d)),
                (f"h{i}.ln2", (d,)),
                (f"h{i}.w1", (d, f)),
                (f"h{i}.w2", (f, d)),
            ]
        out.append(("lnf", (d,)))
        return out

    @property
    def param_count(self) -> int:
        return sum(_prod(s) for _, s in self.layout())


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """MLP image classifier over flattened images (ResNet-50 stand-in)."""

    name: str
    input_dim: int
    hidden: Tuple[int, ...]
    classes: int
    batch: int

    def layout(self) -> List[Tuple[str, Tuple[int, ...]]]:
        dims = (self.input_dim,) + tuple(self.hidden) + (self.classes,)
        out: List[Tuple[str, Tuple[int, ...]]] = []
        for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
            out.append((f"fc{i}.w", (a, b)))
            out.append((f"fc{i}.b", (b,)))
        return out

    @property
    def param_count(self) -> int:
        return sum(_prod(s) for _, s in self.layout())


def _prod(shape: Tuple[int, ...]) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


# ---------------------------------------------------------------------------
# Presets.  Sizes are picked for a single-core CPU-PJRT testbed; the paper's
# full-size configs (GPT-2 124M, Llama-3.1-8B, ResNet-50) enter through the
# analytical memory model on the Rust side (rust/src/memory).
# ---------------------------------------------------------------------------

LM_PRESETS: Dict[str, LmConfig] = {
    # main experiment model (Fig 2a / Fig 5 / Table 3 analog)
    "lm-tiny": LmConfig("lm-tiny", vocab=512, d_model=128, n_layers=4,
                        n_heads=4, seq_len=64, batch=8),
    # larger e2e driver model (quickstart --preset lm-small)
    "lm-small": LmConfig("lm-small", vocab=2048, d_model=256, n_layers=6,
                         n_heads=8, seq_len=128, batch=8),
}

VISION_PRESETS: Dict[str, VisionConfig] = {
    "vision": VisionConfig("vision", input_dim=192, hidden=(256, 128),
                           classes=10, batch=64),
}

# Optimizer-step bucket sizes to lower (elements per bucket).
BUCKET_SIZES = [16384, 65536]

# Standalone kernel round-trip artifact size (cross-validation vs Rust).
KERNEL_VEC = 4096
