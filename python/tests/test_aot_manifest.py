"""AOT manifest consistency (runs only after `make artifacts`)."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST),
    reason="artifacts not built (run `make artifacts`)")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_every_artifact_file_exists(manifest):
    names = []
    for m in manifest["models"].values():
        names += list(m["artifacts"].values())
    for b in manifest["buckets"].values():
        names += list(b["artifacts"].values())
    names += list(manifest["kernels"]["artifacts"].values())
    assert names
    for n in names:
        path = os.path.join(ART, n)
        assert os.path.exists(path), n
        assert os.path.getsize(path) > 100, n


def test_hlo_text_parses_header(manifest):
    for m in manifest["models"].values():
        path = os.path.join(ART, list(m["artifacts"].values())[0])
        head = open(path).read(200)
        assert "HloModule" in head


def test_layout_contiguous(manifest):
    for m in manifest["models"].values():
        off = 0
        for entry in m["layout"]:
            assert entry["offset"] == off
            n = 1
            for s in entry["shape"]:
                n *= s
            off += n
        assert off == m["param_count"]


def test_bucket_sizes_group_aligned(manifest):
    g = manifest["group"]
    for key, b in manifest["buckets"].items():
        assert int(key) == b["size"]
        assert b["size"] % g == 0
        required = ["opt_adamw_ref", "opt_sgd_ref", "opt_lion_ref",
                    "opt_adamw_flash", "opt_sgd_flash", "opt_lion_flash",
                    "opt_adamw_wsplit", "opt_adamw_quant",
                    "opt_adamw_nocompand"]
        for r in required:
            assert r in b["artifacts"], r


def test_hyp_layout_stable(manifest):
    # rust/src/optim/hyper.rs mirrors this order; do not reorder.
    assert manifest["hyp_layout"][:7] == [
        "lr", "beta1", "beta2", "eps", "wd", "bc1", "bc2"]
    assert manifest["nhyp"] == 8
    assert manifest["group"] == 32
