"""Layer-2 model graphs: shapes, gradients, ref/flash track consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model, vision

CFG = configs.LmConfig("t", vocab=64, d_model=32, n_layers=2, n_heads=2,
                       seq_len=16, batch=2)
VCFG = configs.VisionConfig("v", input_dim=48, hidden=(32,), classes=4,
                            batch=8)


def init_params(cfg, rng, scale=0.02):
    return (rng.standard_normal(cfg.param_count) * scale).astype(np.float32)


class TestLmModel:
    def test_layout_covers_buffer(self):
        total = sum(int(np.prod(s)) for _, s in CFG.layout())
        assert total == CFG.param_count

    def test_loss_finite_and_reasonable(self):
        rng = np.random.default_rng(0)
        flat = jnp.asarray(init_params(CFG, rng))
        x = jnp.asarray(rng.integers(0, CFG.vocab, (2, 16)), jnp.int32)
        loss = model.loss_fn(flat, x, x, CFG)
        # near-random init => loss ~ log(vocab)
        assert np.isfinite(float(loss))
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.5

    def test_grads_shape_dtype(self):
        rng = np.random.default_rng(1)
        flat = jnp.asarray(init_params(CFG, rng))
        x = jnp.asarray(rng.integers(0, CFG.vocab, (2, 16)), jnp.int32)
        loss, g = model.fwd_bwd(flat, x, x, CFG)
        assert g.shape == (CFG.param_count,) and g.dtype == jnp.float32
        assert np.isfinite(np.asarray(g)).all()

    def test_flash_track_bf16_grads(self):
        rng = np.random.default_rng(2)
        flat = jnp.asarray(init_params(CFG, rng)).astype(jnp.bfloat16)
        x = jnp.asarray(rng.integers(0, CFG.vocab, (2, 16)), jnp.int32)
        loss, g = model.fwd_bwd(flat, x, x, CFG)
        assert g.dtype == jnp.bfloat16
        assert np.isfinite(float(loss))

    def test_ref_flash_tracks_agree(self):
        """Same params: ref (f32) and flash (bf16) losses nearly equal,
        because ref downcasts to bf16 for compute anyway."""
        rng = np.random.default_rng(3)
        f32 = jnp.asarray(init_params(CFG, rng))
        bf = f32.astype(jnp.bfloat16)
        x = jnp.asarray(rng.integers(0, CFG.vocab, (2, 16)), jnp.int32)
        l_ref = float(model.loss_fn(f32, x, x, CFG))
        l_flash = float(model.loss_fn(bf, x, x, CFG))
        assert abs(l_ref - l_flash) < 0.05

    def test_eval_counts(self):
        rng = np.random.default_rng(4)
        flat = jnp.asarray(init_params(CFG, rng))
        x = jnp.asarray(rng.integers(0, CFG.vocab, (2, 16)), jnp.int32)
        loss_sum, ncorrect = model.evaluate(flat, x, x, CFG)
        assert 0 <= int(ncorrect) <= 32
        assert float(loss_sum) > 0

    def test_causality(self):
        """Changing a future token must not affect earlier logits."""
        rng = np.random.default_rng(5)
        flat = jnp.asarray(init_params(CFG, rng))
        x1 = np.asarray(rng.integers(0, CFG.vocab, (1, 16)), np.int32)
        x2 = x1.copy()
        x2[0, -1] = (x2[0, -1] + 1) % CFG.vocab
        l1 = np.asarray(model.forward_logits(flat, jnp.asarray(x1), CFG))
        l2 = np.asarray(model.forward_logits(flat, jnp.asarray(x2), CFG))
        assert np.array_equal(l1[0, :-1], l2[0, :-1])
        assert not np.array_equal(l1[0, -1], l2[0, -1])

    def test_one_sgd_step_decreases_loss(self):
        rng = np.random.default_rng(6)
        flat = jnp.asarray(init_params(CFG, rng))
        x = jnp.asarray(rng.integers(0, CFG.vocab, (2, 16)), jnp.int32)
        loss0, g = model.fwd_bwd(flat, x, x, CFG)
        loss1 = model.loss_fn(flat - 0.5 * g, x, x, CFG)
        assert float(loss1) < float(loss0)


class TestVisionModel:
    def test_loss_and_grads(self):
        rng = np.random.default_rng(7)
        flat = jnp.asarray(
            (rng.standard_normal(VCFG.param_count) * 0.05).astype(np.float32))
        x = jnp.asarray(rng.standard_normal((8, 48)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)
        loss, g = vision.fwd_bwd(flat, x, y, VCFG)
        assert np.isfinite(float(loss)) and g.shape == (VCFG.param_count,)
        assert abs(float(loss) - np.log(4)) < 1.0

    def test_eval(self):
        rng = np.random.default_rng(8)
        flat = jnp.asarray(
            (rng.standard_normal(VCFG.param_count) * 0.05).astype(np.float32))
        x = jnp.asarray(rng.standard_normal((8, 48)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)
        loss_sum, ncorrect = vision.evaluate(flat, x, y, VCFG)
        assert 0 <= int(ncorrect) <= 8

    def test_learns_separable_task(self):
        """A few SGD steps on a linearly separable task improve accuracy."""
        rng = np.random.default_rng(9)
        protos = rng.standard_normal((4, 48)).astype(np.float32) * 2
        xs = []
        ys = []
        for i in range(4):
            xs.append(protos[i] + rng.standard_normal((16, 48)) * 0.3)
            ys.extend([i] * 16)
        x = jnp.asarray(np.concatenate(xs).astype(np.float32))
        y = jnp.asarray(np.asarray(ys), jnp.int32)
        flat = jnp.asarray(
            (rng.standard_normal(VCFG.param_count) * 0.05).astype(np.float32))
        for _ in range(30):
            _, g = vision.fwd_bwd(flat, x, y, VCFG)
            flat = flat - 0.05 * g
        _, ncorrect = vision.evaluate(flat, x, y, VCFG)
        assert int(ncorrect) > 48  # > 75% on 64 samples
