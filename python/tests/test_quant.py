"""Algorithms 2 & 3 (companded state quantization): kernel + invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref


def heavy_tailed(rng, n, scale=1.0):
    """Student-t-ish heavy tails, like real optimizer states."""
    return (rng.standard_t(3, n) * scale).astype(np.float32)


def nmse(a, b):
    return float(np.mean((a - b) ** 2) / (np.mean(b ** 2) + 1e-30))


class TestCompanding:
    def test_phi_m_inverse(self):
        x = jnp.linspace(-1, 1, 4097)
        z = ref.phi_m(x)
        back = np.asarray(ref.phi_m_inv(z))
        assert np.abs(back - np.asarray(x)).max() < 1e-6

    def test_phi_m_range(self):
        x = jnp.linspace(-1, 1, 1001)
        z = np.asarray(ref.phi_m(x))
        assert z.min() >= -1.0 and z.max() <= 1.0

    def test_companding_beats_linear_momentum(self):
        rng = np.random.default_rng(0)
        m = heavy_tailed(rng, 32768)
        q, s = ref.quant_momentum(jnp.asarray(m))
        lin_q, lin_s = ref.quant_momentum_linear(jnp.asarray(m))
        e_c = nmse(np.asarray(ref.dequant_momentum(q, s)), m)
        e_l = nmse(np.asarray(ref.dequant_momentum_linear(lin_q, lin_s)), m)
        assert e_c < e_l

    def test_companding_beats_linear_variance(self):
        rng = np.random.default_rng(1)
        v = heavy_tailed(rng, 32768) ** 2  # squared-gradient-like
        q, s = ref.quant_variance(jnp.asarray(v))
        lq, ls = ref.quant_variance_linear(jnp.asarray(v))
        e_c = nmse(np.asarray(ref.dequant_variance(q, s)), v)
        e_l = nmse(np.asarray(ref.dequant_variance_linear(lq, ls)), v)
        assert e_c < e_l / 2  # paper: "particularly large" for variance


class TestMomentum:
    def test_kernel_matches_oracle(self):
        """Pallas kernel vs eager oracle: scales bit-exact; codes may
        sit +-1 apart at rounding boundaries (XLA fuses the compiled
        path with FMA; the eager path is strict IEEE)."""
        rng = np.random.default_rng(2)
        m = heavy_tailed(rng, 8192)
        qr, sr = ref.quant_momentum(jnp.asarray(m))
        qk, sk = quant.quant_momentum(jnp.asarray(m))
        d = np.abs(np.asarray(qr, np.int32) - np.asarray(qk, np.int32))
        assert d.max() <= 1 and (d == 1).mean() < 0.01
        assert (np.asarray(sr) == np.asarray(sk)).all()
        dk = np.asarray(quant.dequant_momentum(qk, sk))
        dr = np.asarray(ref.dequant_momentum(qk, sk))
        rel = np.abs(dk - dr) / np.maximum(np.abs(dr), 1e-30)
        assert rel.max() < 1e-6

    def test_zero_group_stable(self):
        m = jnp.zeros(64, jnp.float32)
        q, s = ref.quant_momentum(m)
        out = np.asarray(ref.dequant_momentum(q, s))
        assert (out == 0).all() and np.isfinite(out).all()

    def test_roundtrip_small_error(self):
        rng = np.random.default_rng(3)
        m = heavy_tailed(rng, 32768, scale=1e-3)
        q, s = ref.quant_momentum(jnp.asarray(m))
        assert nmse(np.asarray(ref.dequant_momentum(q, s)), m) < 1e-3

    def test_sign_preserved(self):
        """Nonzero codes preserve sign; a zero code is only allowed for
        values tiny relative to their group absmax."""
        rng = np.random.default_rng(4)
        m = heavy_tailed(rng, 4096)
        q, s = ref.quant_momentum(jnp.asarray(m))
        out = np.asarray(ref.dequant_momentum(q, s))
        qn = np.asarray(q)
        nz = qn != 0
        assert (np.sign(out[nz]) == np.sign(m[nz])).all()
        ga = np.repeat(np.abs(m.reshape(-1, 32)).max(axis=1), 32)
        # softsign: |m|/absmax >~ 1/(2*127) always produces a code
        assert (np.abs(m[~nz]) <= ga[~nz] / 120.0).all()

    def test_absmax_representable(self):
        """The group absmax element must round-trip with <= f16-scale error."""
        rng = np.random.default_rng(5)
        m = heavy_tailed(rng, 4096)
        g = m.reshape(-1, 32)
        idx = np.abs(g).argmax(axis=1)
        q, s = ref.quant_momentum(jnp.asarray(m))
        out = np.asarray(ref.dequant_momentum(q, s)).reshape(-1, 32)
        peak_in = g[np.arange(len(idx)), idx]
        peak_out = out[np.arange(len(idx)), idx]
        rel = np.abs(peak_out - peak_in) / np.abs(peak_in)
        assert rel.max() < 2e-3  # f16 scale rounding ~2^-11 + int8 rounding


class TestVariance:
    def test_kernel_matches_oracle(self):
        rng = np.random.default_rng(6)
        v = heavy_tailed(rng, 8192) ** 2
        qr, sr = ref.quant_variance(jnp.asarray(v))
        qk, sk = quant.quant_variance(jnp.asarray(v))
        d = np.abs(np.asarray(qr, np.int32) - np.asarray(qk, np.int32))
        assert d.max() <= 1 and (d == 1).mean() < 0.01
        assert (np.asarray(sr) == np.asarray(sk)).all()

    def test_nonnegative(self):
        rng = np.random.default_rng(7)
        v = heavy_tailed(rng, 4096) ** 2
        q, s = ref.quant_variance(jnp.asarray(v))
        out = np.asarray(ref.dequant_variance(q, s))
        assert (out >= 0).all()

    def test_zero_group_stable(self):
        v = jnp.zeros(64, jnp.float32)
        q, s = ref.quant_variance(v)
        out = np.asarray(ref.dequant_variance(q, s))
        assert (out == 0).all()

    def test_wide_dynamic_range(self):
        """sqrt companding keeps relative error bounded over ~6 decades
        within a group (the heavy-tail motivation in §3.2)."""
        rng = np.random.default_rng(8)
        v = np.exp(rng.uniform(-14, 0, 32768)).astype(np.float32)
        q, s = ref.quant_variance(jnp.asarray(v))
        out = np.asarray(ref.dequant_variance(q, s))
        lq, ls = ref.quant_variance_linear(jnp.asarray(v))
        lout = np.asarray(ref.dequant_variance_linear(lq, ls))
        assert nmse(out, v) < nmse(lout, v)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=128),
       st.integers(min_value=0, max_value=2 ** 31),
       st.floats(min_value=-4, max_value=3))
def test_momentum_roundtrip_hypothesis(ngroups, seed, logscale):
    # scale range keeps the group absmax inside f16's representable
    # window [~6e-8, 65504] — the paper's f16 group scales saturate
    # outside it (see test_f16_scale_saturation)
    rng = np.random.default_rng(seed)
    m = (rng.standard_normal(32 * ngroups) * 10.0 ** logscale
         ).astype(np.float32)
    q, s = ref.quant_momentum(jnp.asarray(m))
    out = np.asarray(ref.dequant_momentum(q, s))
    # error within each group bounded by a fraction of the group absmax
    ga = np.maximum(np.abs(m.reshape(-1, 32)).max(axis=1, keepdims=True),
                    1e-30)
    rel = np.abs(out - m).reshape(-1, 32) / ga
    assert rel.max() < 0.02  # softsign worst-case bin width near |x|~1/2


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=2 ** 31))
def test_variance_roundtrip_hypothesis(ngroups, seed):
    rng = np.random.default_rng(seed)
    v = (rng.standard_normal(32 * ngroups) ** 2).astype(np.float32)
    q, s = ref.quant_variance(jnp.asarray(v))
    out = np.asarray(ref.dequant_variance(q, s))
    ga = np.maximum(v.reshape(-1, 32).max(axis=1, keepdims=True), 1e-30)
    rel = np.abs(out - v).reshape(-1, 32) / ga
    assert rel.max() < 0.02


def test_f16_scale_saturation_is_graceful():
    """Group absmax beyond the f16 window (the paper stores scales in
    FP16) must not produce NaN/inf state — values degrade but stay
    finite, and the in-window path is unaffected."""
    big = np.full(32, 1e6, np.float32)       # absmax > f16 max
    tiny = np.full(32, 1e-8, np.float32)     # absmax < f16 min subnormal
    for m in (big, tiny):
        q, s = ref.quant_momentum(jnp.asarray(m))
        out = np.asarray(ref.dequant_momentum(q, s))
        assert np.isfinite(out).all()
        v = m ** 2
        qv, sv = ref.quant_variance(jnp.asarray(v))
        outv = np.asarray(ref.dequant_variance(qv, sv))
        assert np.isfinite(outv).all()
        assert (outv >= 0).all()
