"""Fused optimizer step kernels (Algorithms 4/5/6) vs composed oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_steps, ref


def hyp_vec(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, t=10):
    return jnp.asarray([lr, b1, b2, eps, wd,
                        1.0 / (1.0 - b1 ** t), 1.0 / (1.0 - b2 ** t), 0.0],
                       jnp.float32)


def make_state(rng, n, scale=0.1):
    theta = (rng.standard_normal(n) * scale).astype(np.float32)
    tp, rho = ref.split_compress(jnp.asarray(theta))
    m = (rng.standard_normal(n) * 0.01).astype(np.float32)
    v = (rng.standard_normal(n) ** 2 * 1e-4).astype(np.float32)
    mq, ms = ref.quant_momentum(jnp.asarray(m))
    vq, vs = ref.quant_variance(jnp.asarray(v))
    g = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32)
                    ).astype(jnp.bfloat16)
    return tp, rho, mq, ms, vq, vs, g


def assert_all_equal(kernel_out, ref_out, names):
    """Kernel (compiled, FMA-contracted) vs oracle (eager, strict IEEE):
    integer codes within +-1 (rare), floats within 1e-6 relative."""
    for a, b, name in zip(kernel_out, ref_out, names):
        a = np.asarray(a)
        b = np.asarray(b)
        if a.dtype in (np.int8, np.uint8, np.int16):
            d = np.abs(a.astype(np.int32) - b.astype(np.int32))
            assert d.max() <= 1, f"{name}: max code diff {d.max()}"
            assert (d == 1).mean() < 0.01, f"{name}: too many off-by-1"
        else:
            af = a.astype(np.float64)
            bf = b.astype(np.float64)
            rel = np.abs(af - bf) / np.maximum(np.abs(bf), 1e-30)
            # bf16 outputs: one output-ulp; f32: FMA differences can
            # compound through the dequant->update->requant chain
            tol = {2: 1.6e-2, 4: 1e-4}[a.dtype.itemsize]
            if a.dtype == np.float16:
                tol = 2e-3
            assert rel.max() < tol, f"{name}: rel {rel.max()}"


class TestFlashAdamW:
    def test_bitexact_vs_oracle(self):
        rng = np.random.default_rng(0)
        tp, rho, mq, ms, vq, vs, g = make_state(rng, 4096)
        h = hyp_vec()
        out_k = fused_steps.flash_adamw(h, tp, rho, mq, ms, vq, vs, g)
        out_r = ref.flash_adamw_ref(tp, rho, mq, ms, vq, vs, g,
                                    h[0], h[1], h[2], h[3], h[4], h[5], h[6])
        assert_all_equal(out_k, out_r,
                         ["theta_p", "rho", "mq", "ms", "vq", "vs"])

    def test_close_to_fp32_adamw(self):
        """One flash step stays close to the exact fp32 step."""
        rng = np.random.default_rng(1)
        n = 4096
        theta = (rng.standard_normal(n) * 0.1).astype(np.float32)
        m = (rng.standard_normal(n) * 0.01).astype(np.float32)
        v = (rng.standard_normal(n) ** 2 * 1e-4).astype(np.float32)
        g = (rng.standard_normal(n) * 0.01).astype(np.float32)
        h = hyp_vec()
        t_ref, _, _ = ref.adamw_ref(jnp.asarray(theta), jnp.asarray(m),
                                    jnp.asarray(v), jnp.asarray(g),
                                    h[0], h[1], h[2], h[3], h[4], h[5], h[6])
        tp, rho = ref.split_compress(jnp.asarray(theta))
        mq, ms = ref.quant_momentum(jnp.asarray(m))
        vq, vs = ref.quant_variance(jnp.asarray(v))
        gb = jnp.asarray(g).astype(jnp.bfloat16)
        tp2, rho2, *_ = fused_steps.flash_adamw(h, tp, rho, mq, ms, vq, vs,
                                                gb)
        t_flash = np.asarray(ref.split_decompress(tp2, rho2))
        # update magnitude ~ lr=1e-3; bulk agreement well below that.
        # (elements with near-zero variance are legitimately sensitive:
        # quantizing v perturbs 1/sqrt(v_hat), so the max diff can reach
        # the update scale — the 50-step tracking test below bounds the
        # accumulated effect instead)
        diff = np.abs(t_flash - np.asarray(t_ref))
        assert np.median(diff) < 5e-5
        assert np.quantile(diff, 0.99) < 7e-4

    def test_padding_fixed_point(self):
        """All-zero (padding) elements remain exactly zero after a step."""
        n = 2048
        zeros = jnp.zeros(n, jnp.float32)
        tp, rho = ref.split_compress(zeros)
        mq, ms = ref.quant_momentum(zeros)
        vq, vs = ref.quant_variance(zeros)
        g = zeros.astype(jnp.bfloat16)
        out = fused_steps.flash_adamw(hyp_vec(), tp, rho, mq, ms, vq, vs, g)
        assert (np.asarray(out[0], np.float32) == 0).all()
        assert (np.asarray(out[1]) == 0).all()
        assert (np.asarray(out[2]) == 0).all()
        assert (np.asarray(out[4]) == 0).all()

    def test_many_steps_track_fp32(self):
        """Loss-free invariant: 50 flash steps track 50 fp32 steps."""
        rng = np.random.default_rng(2)
        n = 1024
        theta = (rng.standard_normal(n) * 0.1).astype(np.float32)
        tp, rho = ref.split_compress(jnp.asarray(theta))
        mq, ms = ref.quant_momentum(jnp.zeros(n))
        vq, vs = ref.quant_variance(jnp.zeros(n))
        t32 = jnp.asarray(theta)
        m32 = jnp.zeros(n)
        v32 = jnp.zeros(n)
        for t in range(1, 51):
            g = (rng.standard_normal(n) * 0.01).astype(np.float32)
            h = hyp_vec(t=t)
            tp, rho, mq, ms, vq, vs = fused_steps.flash_adamw(
                h, tp, rho, mq, ms, vq, vs, jnp.asarray(g).astype(jnp.bfloat16))
            t32, m32, v32 = ref.adamw_ref(t32, m32, v32, jnp.asarray(g),
                                          h[0], h[1], h[2], h[3], h[4],
                                          h[5], h[6])
        drift = np.abs(np.asarray(ref.split_decompress(tp, rho)) -
                       np.asarray(t32))
        scale = np.abs(np.asarray(t32)) + 1e-3
        assert np.median(drift / scale) < 0.05


class TestFlashSgd:
    def test_bitexact_vs_oracle(self):
        rng = np.random.default_rng(3)
        tp, rho, mq, ms, _, _, g = make_state(rng, 4096)
        h = hyp_vec(lr=0.1, b1=0.9, wd=3e-5)
        out_k = fused_steps.flash_sgd(h, tp, rho, mq, ms, g)
        out_r = ref.flash_sgd_ref(tp, rho, mq, ms, g, h[0], h[1], h[4])
        assert_all_equal(out_k, out_r, ["theta_p", "rho", "mq", "ms"])


class TestFlashLion:
    def test_bitexact_vs_oracle(self):
        rng = np.random.default_rng(4)
        tp, rho, mq, ms, _, _, g = make_state(rng, 4096)
        h = hyp_vec(lr=2e-4)
        out_k = fused_steps.flash_lion(h, tp, rho, mq, ms, g)
        out_r = ref.flash_lion_ref(tp, rho, mq, ms, g, h[0], h[1], h[2],
                                   h[4])
        assert_all_equal(out_k, out_r, ["theta_p", "rho", "mq", "ms"])

    def test_update_is_sign_bounded(self):
        """Lion update magnitude is exactly lr*(1 + wd*|theta|) bounded."""
        rng = np.random.default_rng(5)
        tp, rho, mq, ms, _, _, g = make_state(rng, 1024)
        h = hyp_vec(lr=2e-4, wd=0.0)
        tp2, rho2, _, _ = fused_steps.flash_lion(h, tp, rho, mq, ms, g)
        before = np.asarray(ref.split_decompress(tp, rho))
        after = np.asarray(ref.split_decompress(tp2, rho2))
        # |delta| <= lr + split reconstruction noise of both endpoints
        ulp = np.exp2(np.asarray(ref.ulp_exponent_bf16(tp), np.float64))
        assert (np.abs(after - before) <= 2e-4 * 1.01 + ulp).all()


class TestReferenceSteps:
    def test_ref_adamw_kernel(self):
        rng = np.random.default_rng(6)
        n = 4096
        theta = jnp.asarray((rng.standard_normal(n) * 0.1).astype(np.float32))
        m = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32))
        v = jnp.asarray((rng.standard_normal(n) ** 2 * 1e-4).astype(np.float32))
        g = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32))
        h = hyp_vec()
        out_k = fused_steps.ref_adamw(h, theta, m, v, g)
        out_r = ref.adamw_ref(theta, m, v, g, h[0], h[1], h[2], h[3], h[4],
                              h[5], h[6])
        assert_all_equal(out_k, out_r, ["theta", "m", "v"])

    def test_ref_sgd_and_lion_kernels(self):
        rng = np.random.default_rng(7)
        n = 2048
        theta = jnp.asarray((rng.standard_normal(n) * 0.1).astype(np.float32))
        m = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32))
        g = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32))
        h = hyp_vec(lr=0.1)
        assert_all_equal(fused_steps.ref_sgd(h, theta, m, g),
                         ref.sgd_ref(theta, m, g, h[0], h[1], h[4]),
                         ["theta", "m"])
        assert_all_equal(fused_steps.ref_lion(h, theta, m, g),
                         ref.lion_ref(theta, m, g, h[0], h[1], h[2], h[4]),
                         ["theta", "m"])


class TestAblationSteps:
    def test_wsplit_adamw(self):
        rng = np.random.default_rng(8)
        n = 2048
        theta = (rng.standard_normal(n) * 0.1).astype(np.float32)
        tp, rho = ref.split_compress(jnp.asarray(theta))
        m = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32))
        v = jnp.asarray((rng.standard_normal(n) ** 2 * 1e-4).astype(np.float32))
        g = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32)
                        ).astype(jnp.bfloat16)
        h = hyp_vec()
        out_k = fused_steps.wsplit_adamw(h, tp, rho, m, v, g)
        out_r = ref.wsplit_adamw_ref(tp, rho, m, v, g, h[0], h[1], h[2],
                                     h[3], h[4], h[5], h[6])
        assert_all_equal(out_k, out_r, ["theta_p", "rho", "m", "v"])

    def test_quant_adamw(self):
        rng = np.random.default_rng(9)
        n = 2048
        theta = jnp.asarray((rng.standard_normal(n) * 0.1).astype(np.float32))
        mq, ms = ref.quant_momentum(jnp.zeros(n))
        vq, vs = ref.quant_variance(jnp.zeros(n))
        g = jnp.asarray((rng.standard_normal(n) * 0.01).astype(np.float32))
        h = hyp_vec()
        out_k = fused_steps.quant_adamw(h, theta, mq, ms, vq, vs, g)
        out_r = ref.quant_adamw_ref(theta, mq, ms, vq, vs, g, h[0], h[1],
                                    h[2], h[3], h[4], h[5], h[6])
        assert_all_equal(out_k, out_r, ["theta", "mq", "ms", "vq", "vs"])

    def test_nocompand_adamw(self):
        rng = np.random.default_rng(10)
        tp, rho, _, _, _, _, g = make_state(rng, 2048)
        mq, ms = ref.quant_momentum_linear(jnp.zeros(2048))
        vq, vs = ref.quant_variance_linear(jnp.zeros(2048))
        h = hyp_vec()
        out_k = fused_steps.nocompand_adamw(h, tp, rho, mq, ms, vq, vs, g)
        out_r = ref.nocompand_adamw_ref(tp, rho, mq, ms, vq, vs, g, h[0],
                                        h[1], h[2], h[3], h[4], h[5], h[6])
        assert_all_equal(out_k, out_r,
                         ["theta_p", "rho", "mq", "ms", "vq", "vs"])


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=2 ** 31),
       st.sampled_from([256, 512, 1024]))
def test_fused_adamw_shapes_hypothesis(nblocks, seed, block):
    """Fused kernel matches oracle across bucket/block size combinations."""
    rng = np.random.default_rng(seed)
    n = block * nblocks
    tp, rho, mq, ms, vq, vs, g = make_state(rng, n)
    h = hyp_vec()
    out_k = fused_steps.flash_adamw(h, tp, rho, mq, ms, vq, vs, g,
                                    block=block)
    out_r = ref.flash_adamw_ref(tp, rho, mq, ms, vq, vs, g, h[0], h[1],
                                h[2], h[3], h[4], h[5], h[6])
    # compare reconstructed quantities (raw codes can differ when the
    # FMA-contracted compiled path lands theta on a neighbouring bf16)
    tk = np.asarray(ref.split_decompress(out_k[0], out_k[1]))
    tr = np.asarray(ref.split_decompress(out_r[0], out_r[1]))
    assert np.abs(tk - tr).max() <= np.abs(tr).max() * 2e-2 + 1e-7
    mk = np.asarray(ref.dequant_momentum(out_k[2], out_k[3]))
    mr = np.asarray(ref.dequant_momentum(out_r[2], out_r[3]))
    assert np.abs(mk - mr).max() <= np.abs(mr).max() * 2e-2 + 1e-9
    vk = np.asarray(ref.dequant_variance(out_k[4], out_k[5]))
    vr = np.asarray(ref.dequant_variance(out_r[4], out_r[5]))
    assert np.abs(vk - vr).max() <= np.abs(vr).max() * 2e-2 + 1e-12
