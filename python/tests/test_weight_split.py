"""Algorithm 1 (ULP weight splitting): kernel-vs-oracle + invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, weight_split


def rand_floats(rng, n, lo=-30, hi=10):
    """Log-uniform magnitudes over many binades, both signs."""
    return (rng.standard_normal(n) *
            np.exp2(rng.uniform(lo, hi, n))).astype(np.float32)


SPECIALS = np.array(
    [0.0, -0.0, 1.0, -1.0, 1.5, 2.0 ** -126, -(2.0 ** -126),
     2.0 ** -127, 1e-45, -1e-45, 3.3895e38, 65504.0, 65536.0,
     2.0 ** -133, 1.0 + 2.0 ** -8, 1.0 - 2.0 ** -9], dtype=np.float32)


class TestKernelMatchesOracle:
    @pytest.mark.parametrize("n", [ref.N_INT8, ref.N_INT16])
    def test_compress_bitexact(self, n):
        rng = np.random.default_rng(0)
        theta = np.concatenate([rand_floats(rng, 4096 - len(SPECIALS)),
                                SPECIALS])
        tp_r, rho_r = ref.split_compress(jnp.asarray(theta), n=n)
        tp_k, rho_k = weight_split.split_compress(jnp.asarray(theta), n=n)
        assert (np.asarray(tp_r, np.float32) ==
                np.asarray(tp_k, np.float32)).all()
        assert (np.asarray(rho_r) == np.asarray(rho_k)).all()

    def test_decompress_bitexact(self):
        rng = np.random.default_rng(1)
        theta = rand_floats(rng, 4096)
        tp, rho = ref.split_compress(jnp.asarray(theta))
        out_r = np.asarray(ref.split_decompress(tp, rho))
        out_k = np.asarray(weight_split.split_decompress(tp, rho))
        assert (out_r == out_k).all()


class TestSplitInvariants:
    def test_theta_prime_is_plain_downcast(self):
        """theta' must equal the plain RNE bf16 downcast (drop-in property)."""
        rng = np.random.default_rng(2)
        theta = rand_floats(rng, 2048)
        tp, _ = ref.split_compress(jnp.asarray(theta))
        assert (np.asarray(tp, np.float32) ==
                np.asarray(jnp.asarray(theta).astype(jnp.bfloat16),
                           np.float32)).all()

    @pytest.mark.parametrize("n,bits", [(ref.N_INT8, 8), (ref.N_INT16, 16)])
    def test_error_bound(self, n, bits):
        """|theta_hat - theta| <= ULP/2 * (1/N + quantization half-step)."""
        rng = np.random.default_rng(3)
        theta = rand_floats(rng, 8192)
        tp, rho = ref.split_compress(jnp.asarray(theta), n=n)
        th = np.asarray(ref.split_decompress(tp, rho, n=n))
        ulp = np.exp2(np.asarray(ref.ulp_exponent_bf16(tp), np.float64))
        err = np.abs(th.astype(np.float64) - theta.astype(np.float64))
        # quantization half-step of rho plus the final f32 rounding of
        # theta' + e (comparable in magnitude for the int16 correction)
        f32_round = np.spacing(np.abs(theta)).astype(np.float64) / 2.0
        bound = ulp / 2.0 * (0.5 / n) * 1.001 + f32_round + 1e-45
        assert (err <= bound).all(), float((err / bound).max())

    def test_int16_mostly_exact(self):
        """Paper §4.4: 16-bit correction reconstructs BF16-split FP32
        bitwise in ~99.92% of cases."""
        rng = np.random.default_rng(4)
        theta = rand_floats(rng, 65536)
        tp, rho = ref.split_compress(jnp.asarray(theta), n=ref.N_INT16)
        th = np.asarray(ref.split_decompress(tp, rho, n=ref.N_INT16))
        exact = (th.view(np.uint32) == theta.view(np.uint32)).mean()
        assert exact > 0.99

    def test_zero_maps_to_zero(self):
        tp, rho = ref.split_compress(jnp.zeros(32, jnp.float32))
        th = np.asarray(ref.split_decompress(tp, rho))
        assert (th == 0).all() and (np.asarray(rho) == 0).all()

    def test_f16_target(self):
        rng = np.random.default_rng(5)
        theta = rand_floats(rng, 4096, lo=-12, hi=4)  # fp16 range
        tp, rho = ref.split_compress(jnp.asarray(theta), n=ref.N_INT16,
                                     target=jnp.float16)
        th = np.asarray(ref.split_decompress(tp, rho, n=ref.N_INT16))
        rel = np.abs(th - theta) / np.maximum(np.abs(theta), 1e-30)
        # paper Fig 3 (bottom): our 32-bit FP16 format ~perfect in
        # the normal range; allow slack for the subnormal edge
        assert np.median(rel) < 1e-7
        tp_k, rho_k = weight_split.split_compress(
            jnp.asarray(theta), n=ref.N_INT16, target_name="float16")
        assert (np.asarray(tp_k, np.float32) ==
                np.asarray(tp, np.float32)).all()
        assert (np.asarray(rho_k) == np.asarray(rho)).all()

    def test_better_than_bf16_alone(self):
        rng = np.random.default_rng(6)
        theta = rand_floats(rng, 8192)
        tp, rho = ref.split_compress(jnp.asarray(theta))
        th = np.asarray(ref.split_decompress(tp, rho))
        err_split = np.abs(th - theta)
        err_bf16 = np.abs(np.asarray(tp, np.float32) - theta)
        # ~2^8 improvement on average; require >= 32x in aggregate
        assert err_split.mean() * 32 < err_bf16.mean()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(width=32, allow_nan=False, allow_infinity=False),
                min_size=32, max_size=32))
def test_roundtrip_bound_hypothesis(vals):
    theta = np.asarray(vals, np.float32)
    tp, rho = ref.split_compress(jnp.asarray(theta))
    th = np.asarray(ref.split_decompress(tp, rho))
    tpf = np.asarray(tp, np.float32)
    # exclusions (all XLA-CPU flush-to-zero artifacts; the rust mirror
    # rounds these exactly, see DESIGN.md §8b):
    #  * |theta| > bf16 max downcasts to inf (like plain bf16)
    #  * f32-subnormal theta flushes to zero in the downcast (paper
    #    footnote 1) — error bounded by |theta| < 2^-126
    #  * theta close above f32-min-normal has a *subnormal rounding
    #    error* e = theta - theta', which FTZ flushes; the correction
    #    degrades to the plain-downcast bound ULP/2 (< 2^-131) there.
    finite = np.isfinite(np.where(np.isfinite(theta), tpf, np.inf))
    ok = finite & (np.abs(theta) >= np.float32(2.0 ** -117))
    ulp = np.exp2(np.asarray(ref.ulp_exponent_bf16(tp), np.float64))
    err = np.abs(th.astype(np.float64) - theta.astype(np.float64))
    with np.errstate(over="ignore"):
        f32_round = np.where(
            np.isfinite(theta),
            np.spacing(np.abs(theta)), 0.0).astype(np.float64) / 2.0
    bound = ulp / 2.0 * (0.5 / 127) * 1.001 + f32_round + 1e-45
    assert (err[ok] <= bound[ok]).all()
    # the flush-affected band still reconstructs within the
    # no-correction half-ULP bound, plus |theta| itself for
    # f32-subnormal inputs the downcast flushes to zero entirely
    low = finite & ~ok
    low_bound = ulp / 2.0 * 1.001 + np.abs(theta).astype(np.float64)
    assert (err[low] <= low_bound[low] + 1e-45).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=2 ** 31))
def test_kernel_shapes_hypothesis(nblocks, seed):
    """Kernel agrees with oracle across block-boundary shapes."""
    rng = np.random.default_rng(seed)
    theta = rand_floats(rng, 32 * nblocks)
    tp_r, rho_r = ref.split_compress(jnp.asarray(theta))
    tp_k, rho_k = weight_split.split_compress(jnp.asarray(theta), block=256)
    assert (np.asarray(tp_r, np.float32) ==
            np.asarray(tp_k, np.float32)).all()
    assert (np.asarray(rho_r) == np.asarray(rho_k)).all()
